//! Structural linter over tape programs.
//!
//! Checks a [`Program`] the way a compiler front-end would: per-op shape
//! consistency, operand ordering (append-only DAG), `requires_grad`
//! conventions (non-leaf nodes always carry the flag; gradient flow stops
//! at no-grad input leaves), scalar-loss root, dead-node / dead-parameter
//! reachability, and fusable-chain opportunities (as `Info` diagnostics,
//! actioned by [`super::rewrite`]).  `Tape::backward` runs this in debug
//! builds on every step via `Tape::debug_validate`, so the checks must
//! hold for every graph the apps actually record — errors are reserved
//! for structurally impossible tapes, warnings for legal-but-suspect ones.

use std::fmt;

use super::ir::{OpIr, Program};
use super::rewrite;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warning,
    Error,
}

impl Severity {
    pub fn label(&self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One diagnostic, anchored to a node.
#[derive(Debug, Clone)]
pub struct Diag {
    pub severity: Severity,
    pub node: usize,
    pub message: String,
}

impl fmt::Display for Diag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} @%{}: {}", self.severity.label(), self.node, self.message)
    }
}

/// All diagnostics from one [`lint`] run.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    pub diags: Vec<Diag>,
}

impl LintReport {
    fn push(&mut self, severity: Severity, node: usize, message: String) {
        self.diags.push(Diag { severity, node, message });
    }

    /// Error-severity diagnostics (owned — callable on a temporary report).
    pub fn errors(&self) -> Vec<Diag> {
        self.diags.iter().filter(|d| d.severity == Severity::Error).cloned().collect()
    }

    pub fn warnings(&self) -> Vec<Diag> {
        self.diags.iter().filter(|d| d.severity == Severity::Warning).cloned().collect()
    }

    /// (errors, warnings, infos)
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for d in &self.diags {
            match d.severity {
                Severity::Error => c.0 += 1,
                Severity::Warning => c.1 += 1,
                Severity::Info => c.2 += 1,
            }
        }
        c
    }

    pub fn is_clean(&self) -> bool {
        self.diags.is_empty()
    }
}

impl fmt::Display for LintReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.diags.is_empty() {
            return writeln!(f, "lint clean: no diagnostics");
        }
        for d in &self.diags {
            writeln!(f, "{d}")?;
        }
        Ok(())
    }
}

/// Lint `prog` with `root` as the intended loss node.
pub fn lint(prog: &Program, root: usize) -> LintReport {
    let mut rep = LintReport::default();
    let n = prog.nodes.len();

    if n == 0 {
        rep.push(Severity::Error, 0, "empty program".into());
        return rep;
    }

    // Per-node structural checks.  Operand-order violations make shape
    // checks meaningless for that node, so they short-circuit it.
    for i in 0..n {
        let node = &prog.nodes[i];
        let mut ordered = true;
        for d in node.op.operands() {
            if d >= i {
                rep.push(
                    Severity::Error,
                    i,
                    format!(
                        "{} operand %{d} is not defined before this node \
                         (tape programs are append-only DAGs)",
                        node.op.name()
                    ),
                );
                ordered = false;
            }
        }
        if !ordered {
            continue;
        }
        if !node.op.replayable() {
            rep.push(
                Severity::Error,
                i,
                format!(
                    "{} node is not replayable standalone: the exported payload \
                     cannot rebuild it on a fresh tape, which silently shrinks \
                     the fuzzer's and synthesizer's reachable pattern space",
                    node.op.name()
                ),
            );
        }
        if !matches!(node.op, OpIr::Leaf) && !node.requires_grad {
            rep.push(
                Severity::Error,
                i,
                format!(
                    "non-leaf {} node marked no-grad: the tape records every \
                     interior node as differentiable (gradient flow is cut \
                     only at no-grad input leaves)",
                    node.op.name()
                ),
            );
        }
        check_shapes(prog, i, &mut rep);
    }

    // Root checks.
    if root >= n {
        rep.push(Severity::Error, root, format!("root node out of range (program has {n} nodes)"));
        return rep;
    }
    let r = &prog.nodes[root];
    if r.rows != 1 || r.cols != 1 {
        rep.push(
            Severity::Error,
            root,
            format!("root must be a scalar loss node, got {}x{} {}", r.rows, r.cols, r.op.name()),
        );
    }
    if !r.requires_grad {
        rep.push(
            Severity::Warning,
            root,
            "loss does not depend on any trainable parameter (backward is a no-op)".into(),
        );
    }

    // Reachability: dead parameters, dead compute, unused inputs.
    let seen = prog.reachable(root);
    for i in 0..n {
        if seen[i] {
            continue;
        }
        let node = &prog.nodes[i];
        match (&node.op, node.requires_grad) {
            (OpIr::Leaf, true) => rep.push(
                Severity::Warning,
                i,
                "trainable parameter is unreachable from the loss: no gradient will reach it"
                    .into(),
            ),
            (OpIr::Leaf, false) => {
                rep.push(Severity::Info, i, "input leaf is never consumed".into())
            }
            _ => rep.push(
                Severity::Warning,
                i,
                format!("dead {} node: computed but unreachable from the loss", node.op.name()),
            ),
        }
    }

    // Rewrite opportunities (actioned by the synthesized, bit-proven
    // ruleset; reported here so `lint-tape` surfaces what the rewriter
    // would do to the real training graph).
    let rules = rewrite::admitted_ruleset();
    for cand in rewrite::find(prog, rules) {
        rep.push(
            Severity::Info,
            cand.root,
            format!("fusable by admitted ruleset: {}", cand.describe(rules)),
        );
    }

    rep
}

/// One counter-keyed stochastic-rounding dither coordinate an app
/// registers: the `(stream, tensor_id)` pair that, together with the run
/// seed and step counter, keys its rounding-noise stream.
#[derive(Debug, Clone)]
pub struct DitherCoord {
    /// Human-readable owner (e.g. `sgd:w0`, `lsq:scales`).
    pub label: String,
    pub stream: u64,
    pub tensor_id: u64,
}

impl DitherCoord {
    pub fn new(label: impl Into<String>, stream: u64, tensor_id: u64) -> Self {
        DitherCoord { label: label.into(), stream, tensor_id }
    }
}

/// Static dither-key collision lint.
///
/// Two tensors sharing a `(stream, tensor_id)` coordinate draw the *same*
/// rounding-noise sequence every step — correlated dither that silently
/// voids the unbiased-rounding argument and, worse, makes two optimizers'
/// updates statistically dependent.  Duplicate coordinates are therefore
/// errors; the diagnostic's node index is the offending coordinate's
/// position in `coords`.
pub fn lint_dither_coords(coords: &[DitherCoord]) -> LintReport {
    let mut rep = LintReport::default();
    let mut seen: std::collections::HashMap<(u64, u64), usize> = std::collections::HashMap::new();
    for (i, c) in coords.iter().enumerate() {
        match seen.entry((c.stream, c.tensor_id)) {
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(i);
            }
            std::collections::hash_map::Entry::Occupied(e) => {
                let first = &coords[*e.get()];
                rep.push(
                    Severity::Error,
                    i,
                    format!(
                        "dither-key collision: `{}` and `{}` both key their SR \
                         noise at (stream={:#x}, tensor_id={}) — their rounding \
                         dither is bit-for-bit correlated",
                        first.label, c.label, c.stream, c.tensor_id
                    ),
                );
            }
        }
    }
    rep
}

/// Shape rules per op.  `i`'s operands are known to be `< i`.
fn check_shapes(prog: &Program, i: usize, rep: &mut LintReport) {
    let node = &prog.nodes[i];
    let shape = |d: usize| (prog.nodes[d].rows, prog.nodes[d].cols);
    let mut err = |msg: String| rep.push(Severity::Error, i, msg);
    let out = (node.rows, node.cols);
    match &node.op {
        OpIr::Leaf => {}
        OpIr::MatMul(a, b) => {
            let ((m, ka), (kb, c)) = (shape(*a), shape(*b));
            if ka != kb {
                err(format!("matmul inner dims disagree: %{a} is {m}x{ka}, %{b} is {kb}x{c}"));
            }
            if out != (m, c) {
                err(format!("matmul output should be {m}x{c}, recorded {}x{}", out.0, out.1));
            }
        }
        OpIr::MatMulNT(a, b) => {
            let ((m, ka), (r, kb)) = (shape(*a), shape(*b));
            if ka != kb {
                err(format!("matmul_nt inner dims disagree: %{a} is {m}x{ka}, %{b} is {r}x{kb}"));
            }
            if out != (m, r) {
                err(format!("matmul_nt output should be {m}x{r}, recorded {}x{}", out.0, out.1));
            }
        }
        OpIr::Add(a, b) | OpIr::Sub(a, b) | OpIr::Mul(a, b) => {
            let (sa, sb) = (shape(*a), shape(*b));
            if sa != sb {
                err(format!(
                    "{} operands disagree: %{a} is {}x{}, %{b} is {}x{}",
                    node.op.name(),
                    sa.0,
                    sa.1,
                    sb.0,
                    sb.1
                ));
            }
            if out != sa {
                err(format!("{} output shape drifts from operands", node.op.name()));
            }
        }
        OpIr::AddRow(a, b) => {
            let (sa, sb) = (shape(*a), shape(*b));
            if sb.0 != 1 || sb.1 != sa.1 {
                err(format!(
                    "add_row bias %{b} must be 1x{} to broadcast over %{a} ({}x{}), got {}x{}",
                    sa.1, sa.0, sa.1, sb.0, sb.1
                ));
            }
            if out != sa {
                err("add_row output shape drifts from input".into());
            }
        }
        OpIr::Affine { x, w, b, .. } => {
            let ((m, kx), (kw, c), (br, bc)) = (shape(*x), shape(*w), shape(*b));
            if kx != kw {
                err(format!("affine inner dims disagree: %{x} is {m}x{kx}, %{w} is {kw}x{c}"));
            }
            if br != 1 || bc != c {
                err(format!("affine bias %{b} must be 1x{c}, got {br}x{bc}"));
            }
            if out != (m, c) {
                err(format!("affine output should be {m}x{c}, recorded {}x{}", out.0, out.1));
            }
        }
        OpIr::Relu(a) | OpIr::Sigmoid(a) | OpIr::Tanh(a) | OpIr::Scale(a, _) => {
            if out != shape(*a) {
                err(format!("{} output shape drifts from input %{a}", node.op.name()));
            }
        }
        OpIr::GatherRows { x, idx } => {
            let (xr, xc) = shape(*x);
            if let Some(bad) = idx.iter().find(|&&r| r >= xr) {
                err(format!("gather index {bad} out of range for %{x} with {xr} rows"));
            }
            if out != (idx.len(), xc) {
                err(format!(
                    "gather_rows output should be {}x{xc}, recorded {}x{}",
                    idx.len(),
                    out.0,
                    out.1
                ));
            }
        }
        OpIr::ConcatCols(parts) => {
            if parts.is_empty() {
                err("concat_cols of zero parts".into());
                return;
            }
            let rows = shape(parts[0]).0;
            let mut cols = 0;
            for p in parts {
                let (pr, pc) = shape(*p);
                if pr != rows {
                    err(format!("concat_cols part %{p} has {pr} rows, expected {rows}"));
                }
                cols += pc;
            }
            if out != (rows, cols) {
                err(format!(
                    "concat_cols output should be {rows}x{cols}, recorded {}x{}",
                    out.0, out.1
                ));
            }
        }
        OpIr::LayerNorm { x, .. } => {
            if out != shape(*x) {
                err(format!("layernorm output shape drifts from input %{x}"));
            }
        }
        OpIr::CausalAttn { q, k, v, seqs } => {
            let (sq, sk, sv) = (shape(*q), shape(*k), shape(*v));
            if sk != sq || sv != sq {
                err(format!(
                    "causal_attn q/k/v shapes disagree: {}x{} / {}x{} / {}x{}",
                    sq.0, sq.1, sk.0, sk.1, sv.0, sv.1
                ));
            }
            if *seqs == 0 || sq.0 % seqs != 0 {
                err(format!("causal_attn rows {} not divisible into {seqs} sequences", sq.0));
            }
            if out != sq {
                err("causal_attn output shape drifts from q".into());
            }
        }
        OpIr::SoftmaxXent { logits, targets } => {
            let (lr, lc) = shape(*logits);
            if targets.len() != lr {
                err(format!("softmax_xent has {} targets for {lr} logit rows", targets.len()));
            }
            if let Some(bad) = targets.iter().find(|&&t| t >= lc) {
                err(format!("softmax_xent target class {bad} out of range for {lc} columns"));
            }
            if out != (1, 1) {
                err("softmax_xent must produce a scalar".into());
            }
        }
        OpIr::MeanAll(a) => {
            let (ar, ac) = shape(*a);
            if ar * ac == 0 {
                rep.push(Severity::Warning, i, format!("mean_all over empty %{a} is NaN"));
            }
            if out != (1, 1) {
                rep.push(Severity::Error, i, "mean_all must produce a scalar".into());
            }
        }
        OpIr::MseLoss { diff } => {
            let (dr, dc) = shape(*diff);
            if dr * dc == 0 {
                rep.push(Severity::Warning, i, format!("mse_loss over empty %{diff} is NaN"));
            }
            if out != (1, 1) {
                rep.push(Severity::Error, i, "mse_loss must produce a scalar".into());
            }
        }
        OpIr::BceLoss { logits, labels } => {
            let (lr, lc) = shape(*logits);
            if labels.len() != lr * lc {
                err(format!(
                    "bce_loss has {} labels for {lr}x{lc} logits",
                    labels.len()
                ));
            }
            if out != (1, 1) {
                rep.push(Severity::Error, i, "bce_loss must produce a scalar".into());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::ir::NodeIr;
    use super::*;

    fn leaf(rows: usize, cols: usize, rg: bool) -> NodeIr {
        NodeIr { op: OpIr::Leaf, rows, cols, requires_grad: rg }
    }

    fn node(op: OpIr, rows: usize, cols: usize) -> NodeIr {
        NodeIr { op, rows, cols, requires_grad: true }
    }

    #[test]
    fn clean_program_has_no_errors() {
        let prog = Program {
            nodes: vec![
                leaf(2, 3, false),
                leaf(3, 4, true),
                node(OpIr::MatMul(0, 1), 2, 4),
                node(OpIr::SoftmaxXent { logits: 2, targets: vec![1, 3] }, 1, 1),
            ],
        };
        let rep = lint(&prog, 3);
        assert!(rep.errors().is_empty(), "{rep}");
    }

    #[test]
    fn shape_mismatch_is_an_error() {
        let prog = Program {
            nodes: vec![
                leaf(2, 3, true),
                leaf(4, 2, true), // inner dim 3 != 4
                node(OpIr::MatMul(0, 1), 2, 2),
                node(OpIr::MeanAll(2), 1, 1),
            ],
        };
        let errs = lint(&prog, 3).errors();
        assert!(!errs.is_empty());
        assert!(errs[0].to_string().contains("inner dims"), "{}", errs[0]);
    }

    #[test]
    fn forward_operand_reference_is_an_error() {
        let prog = Program {
            nodes: vec![node(OpIr::Relu(1), 2, 2), leaf(2, 2, true)],
        };
        let errs = lint(&prog, 1);
        assert!(errs.errors().iter().any(|d| d.to_string().contains("append-only")));
    }

    #[test]
    fn dead_parameter_is_a_warning_not_error() {
        let prog = Program {
            nodes: vec![
                leaf(2, 2, true),
                leaf(2, 2, true), // dead param
                node(OpIr::MeanAll(0), 1, 1),
            ],
        };
        let rep = lint(&prog, 2);
        assert!(rep.errors().is_empty(), "{rep}");
        assert_eq!(rep.warnings().len(), 1);
        assert!(rep.warnings()[0].to_string().contains("unreachable"));
    }

    #[test]
    fn non_scalar_root_is_an_error() {
        let prog = Program { nodes: vec![leaf(2, 2, true), node(OpIr::Relu(0), 2, 2)] };
        let errs = lint(&prog, 1).errors();
        assert!(errs.iter().any(|d| d.to_string().contains("scalar loss")));
    }

    #[test]
    fn fusable_chain_reported_as_info() {
        let prog = Program {
            nodes: vec![
                leaf(2, 3, false),
                leaf(3, 4, true),
                leaf(1, 4, true),
                node(OpIr::MatMul(0, 1), 2, 4),
                node(OpIr::AddRow(3, 2), 2, 4),
                node(OpIr::Relu(4), 2, 4),
                node(OpIr::MeanAll(5), 1, 1),
            ],
        };
        let rep = lint(&prog, 6);
        assert!(rep.errors().is_empty(), "{rep}");
        assert!(rep.diags.iter().any(|d| {
            d.severity == Severity::Info && d.message.contains("fusable")
        }));
    }

    #[test]
    fn dither_coord_collision_is_an_error() {
        let coords = vec![
            DitherCoord::new("sgd:w0", 0x0907, 0),
            DitherCoord::new("sgd:w1", 0x0907, 1),
            DitherCoord::new("lsq:scales", 0x5352, 0),
            DitherCoord::new("rogue", 0x0907, 1),
        ];
        let rep = lint_dither_coords(&coords);
        let errs = rep.errors();
        assert_eq!(errs.len(), 1, "{rep}");
        assert_eq!(errs[0].node, 3);
        assert!(errs[0].message.contains("sgd:w1"), "{}", errs[0]);
        assert!(errs[0].message.contains("rogue"), "{}", errs[0]);
    }

    #[test]
    fn unique_dither_coords_are_clean() {
        let coords = vec![
            DitherCoord::new("sgd:w0", 0x0907, 0),
            DitherCoord::new("sgd:b0", 0x0907, 1),
            DitherCoord::new("lsq:scales", 0x5352, 0),
        ];
        assert!(lint_dither_coords(&coords).is_clean());
    }
}
