//! Replay a tape program on a fresh [`Tape`] under an arbitrary policy,
//! backend and intra-thread count.
//!
//! This is the differential-testing primitive: the fuzzer and the rewrite
//! validator both run the *same* [`Program`] + leaf tensors through
//! [`run`] with different `(QPolicy, threads)` pairs and demand bitwise
//! identical values, gradients and loss — the repo's determinism contract
//! made mechanically checkable.

use std::sync::Arc;

use super::ir::{OpIr, Program};
use crate::qsim::{Pool, QPolicy, Tape, Tensor, Var};

/// Everything observable from one replay.
#[derive(Debug, Clone)]
pub struct Replay {
    /// Scalar loss (the root node, mean-capped if the program's last node
    /// is not already scalar).
    pub loss: f32,
    /// Forward value of every program node, by node index.
    pub values: Vec<Tensor>,
    /// Gradient of every program node after backward (`None` where the
    /// tape accumulated nothing, e.g. no-grad input leaves).
    pub grads: Vec<Option<Tensor>>,
}

/// Replay `prog` with `leaves` feeding its leaf nodes in order.
///
/// A non-scalar final node is capped with `mean_all` so backward always
/// runs; the cap node is not part of the reported `values`/`grads`.
pub fn run(
    prog: &Program,
    leaves: &[Tensor],
    policy: QPolicy,
    threads: usize,
) -> Result<Replay, String> {
    let pool = if threads <= 1 { Pool::single() } else { Arc::new(Pool::new(threads)) };
    let mut t = Tape::with_pool(policy, pool);
    let mut vars: Vec<Var> = Vec::with_capacity(prog.nodes.len());
    let mut next_leaf = 0usize;
    for (i, n) in prog.nodes.iter().enumerate() {
        let at = |d: &usize| vars[*d];
        let v = match &n.op {
            OpIr::Leaf => {
                let Some(src) = leaves.get(next_leaf) else {
                    return Err(format!(
                        "program needs more leaves than the {} supplied",
                        leaves.len()
                    ));
                };
                next_leaf += 1;
                if src.rows != n.rows || src.cols != n.cols {
                    return Err(format!(
                        "leaf %{i} expects {}x{}, got {}x{}",
                        n.rows, n.cols, src.rows, src.cols
                    ));
                }
                if n.requires_grad {
                    t.param(src.clone())
                } else {
                    t.input(src.clone())
                }
            }
            OpIr::MatMul(a, b) => t.matmul(at(a), at(b)),
            OpIr::Add(a, b) => t.add(at(a), at(b)),
            OpIr::Sub(a, b) => t.sub(at(a), at(b)),
            OpIr::Mul(a, b) => t.mul(at(a), at(b)),
            OpIr::Relu(a) => t.relu(at(a)),
            OpIr::Sigmoid(a) => t.sigmoid(at(a)),
            OpIr::Tanh(a) => t.tanh(at(a)),
            OpIr::GatherRows { x, idx } => t.gather_rows(at(x), idx.clone()),
            OpIr::MeanAll(a) => t.mean_all(at(a)),
            OpIr::MseLoss { diff } => t.mse_of(at(diff)),
            OpIr::BceLoss { logits, labels } => {
                let ln = &prog.nodes[*logits];
                let lt = Tensor::from_vec(ln.rows, ln.cols, labels.clone());
                t.bce_loss_from(at(logits), &lt)
            }
            OpIr::AddRow(a, b) => t.add_row(at(a), at(b)),
            OpIr::Affine { x, w, b, relu } => t.affine(at(x), at(w), at(b), *relu),
            OpIr::ConcatCols(parts) => {
                let vs: Vec<Var> = parts.iter().map(at).collect();
                t.concat_cols(vs)
            }
            OpIr::Scale(a, c) => t.scale(at(a), *c),
            OpIr::MatMulNT(a, b) => t.matmul_nt(at(a), at(b)),
            OpIr::LayerNorm { x, eps } => t.layernorm(at(x), *eps),
            OpIr::CausalAttn { q, k, v, seqs } => {
                t.causal_attention(at(q), at(k), at(v), *seqs)
            }
            OpIr::SoftmaxXent { logits, targets } => {
                t.softmax_xent(at(logits), targets.clone())
            }
        };
        vars.push(v);
    }
    if next_leaf != leaves.len() {
        return Err(format!(
            "{} leaf tensors supplied but the program only has {next_leaf} leaf nodes",
            leaves.len()
        ));
    }
    let Some(&last) = vars.last() else {
        return Err("empty program".into());
    };
    let scalar = {
        let v = t.value(last);
        v.rows == 1 && v.cols == 1
    };
    let root = if scalar { last } else { t.mean_all(last) };
    let loss = t.value(root).item();
    let values: Vec<Tensor> = vars.iter().map(|&v| t.value(v).clone()).collect();
    t.backward(root);
    let grads: Vec<Option<Tensor>> = vars.iter().map(|&v| t.grad(v).cloned()).collect();
    Ok(Replay { loss, values, grads })
}

/// Bitwise tensor equality (NaN-stable: compares the f32 payload bits).
pub fn bits_equal(a: &Tensor, b: &Tensor) -> bool {
    a.rows == b.rows
        && a.cols == b.cols
        && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// First divergence between two replays of the same program, or `None`.
pub fn diff_replays(a: &Replay, b: &Replay) -> Option<String> {
    if a.loss.to_bits() != b.loss.to_bits() {
        return Some(format!("loss differs: {:e} vs {:e}", a.loss, b.loss));
    }
    for (i, (x, y)) in a.values.iter().zip(&b.values).enumerate() {
        if !bits_equal(x, y) {
            let e = first_bit_diff(x, y);
            return Some(format!(
                "forward value of %{i} differs (first at element {e}: {:e} vs {:e})",
                x.data[e], y.data[e]
            ));
        }
    }
    for (i, (x, y)) in a.grads.iter().zip(&b.grads).enumerate() {
        match (x, y) {
            (None, None) => {}
            (Some(x), Some(y)) if bits_equal(x, y) => {}
            (Some(x), Some(y)) => {
                let e = first_bit_diff(x, y);
                return Some(format!(
                    "gradient of %{i} differs (first at element {e}: {:e} vs {:e})",
                    x.data[e], y.data[e]
                ));
            }
            _ => return Some(format!("gradient of %{i} present in one replay only")),
        }
    }
    None
}

fn first_bit_diff(a: &Tensor, b: &Tensor) -> usize {
    a.data
        .iter()
        .zip(&b.data)
        .position(|(x, y)| x.to_bits() != y.to_bits())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::super::ir::NodeIr;
    use super::*;
    use crate::precision::BF16;
    use crate::qsim::Backend;

    fn leaf(rows: usize, cols: usize, rg: bool) -> NodeIr {
        NodeIr { op: OpIr::Leaf, rows, cols, requires_grad: rg }
    }

    fn node(op: OpIr, rows: usize, cols: usize) -> NodeIr {
        NodeIr { op, rows, cols, requires_grad: true }
    }

    fn tiny_program() -> (Program, Vec<Tensor>) {
        let prog = Program {
            nodes: vec![
                leaf(2, 3, false),
                leaf(3, 2, true),
                leaf(1, 2, true),
                node(OpIr::MatMul(0, 1), 2, 2),
                node(OpIr::AddRow(3, 2), 2, 2),
                node(OpIr::Relu(4), 2, 2),
                node(OpIr::SoftmaxXent { logits: 5, targets: vec![0, 1] }, 1, 1),
            ],
        };
        let leaves = vec![
            Tensor::from_vec(2, 3, vec![0.4, -1.2, 0.7, 1.5, 0.2, -0.3]),
            Tensor::from_vec(3, 2, vec![0.3, -0.7, 1.2, 0.5, -0.2, 0.9]),
            Tensor::from_vec(1, 2, vec![0.1, -0.1]),
        ];
        (prog, leaves)
    }

    #[test]
    fn replay_matches_direct_tape_build_bitwise() {
        let (prog, leaves) = tiny_program();
        let rep = run(&prog, &leaves, QPolicy::new(BF16), 1).unwrap();

        let mut t = Tape::new(QPolicy::new(BF16));
        let x = t.input(leaves[0].clone());
        let w = t.param(leaves[1].clone());
        let b = t.param(leaves[2].clone());
        let mm = t.matmul(x, w);
        let ar = t.add_row(mm, b);
        let h = t.relu(ar);
        let l = t.softmax_xent(h, vec![0, 1]);
        t.backward(l);

        assert_eq!(rep.loss.to_bits(), t.value(l).item().to_bits());
        assert!(bits_equal(&rep.values[5], t.value(h)));
        assert!(bits_equal(rep.grads[1].as_ref().unwrap(), t.grad(w).unwrap()));
        assert!(rep.grads[0].is_none(), "input leaf must not accumulate a gradient");
    }

    #[test]
    fn non_scalar_tail_is_mean_capped() {
        let prog = Program {
            nodes: vec![leaf(2, 2, true), node(OpIr::Relu(0), 2, 2)],
        };
        let leaves = vec![Tensor::from_vec(2, 2, vec![1.0, -2.0, 3.0, -4.0])];
        let rep = run(&prog, &leaves, QPolicy::exact(), 1).unwrap();
        assert_eq!(rep.loss, 1.0); // mean(relu([1,-2,3,-4])) = (1+0+3+0)/4
        assert!(rep.grads[0].is_some());
    }

    #[test]
    fn backend_parity_on_the_tiny_program() {
        let (prog, leaves) = tiny_program();
        let fast = run(&prog, &leaves, QPolicy::with_backend(BF16, Backend::Fast), 1).unwrap();
        let refr =
            run(&prog, &leaves, QPolicy::with_backend(BF16, Backend::Reference), 1).unwrap();
        let fast4 = run(&prog, &leaves, QPolicy::with_backend(BF16, Backend::Fast), 4).unwrap();
        assert!(diff_replays(&fast, &refr).is_none());
        assert!(diff_replays(&fast, &fast4).is_none());
    }

    #[test]
    fn leaf_count_mismatch_is_an_error() {
        let (prog, mut leaves) = tiny_program();
        leaves.pop();
        assert!(run(&prog, &leaves, QPolicy::exact(), 1).is_err());
    }
}
