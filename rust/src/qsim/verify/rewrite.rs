//! Pattern → fused-kernel rewriter over tape programs, with bit-identity
//! admission.
//!
//! Rules (both target the tape's fused [`Affine`](OpIr::Affine) op, which
//! folds the bias add — and optionally the relu — into the producing
//! matmul panel so the `add_row` output round happens in-register):
//!
//! - `FuseAffine`:     `matmul + add_row`        → `affine(relu=false)`
//! - `FuseAffineRelu`: `matmul + add_row + relu` → `affine(relu=true)`
//!
//! A candidate only *matches* when every interior node of the chain is
//! single-use (fusing a multi-use matmul would drop a value other nodes
//! read).  A matched rewrite is only *admitted* when [`validate`] proves
//! the rewritten program bit-identical to the original — loss, every leaf
//! gradient, and the final forward value — across both backends, 1 and 4
//! intra-threads, and the format sweep.  The fuzzer runs this admission
//! check on every generated candidate, so the `Tape::affine` fast path
//! stays pinned to the unfused semantics it replaces.

use super::exec;
use super::ir::{NodeIr, OpIr, Program};
use crate::precision::{BF16, E8M5, FP16, FP32};
use crate::qsim::{Backend, QPolicy, Tensor};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    FuseAffine,
    FuseAffineRelu,
}

impl Rule {
    pub fn name(&self) -> &'static str {
        match self {
            Rule::FuseAffine => "fuse-affine",
            Rule::FuseAffineRelu => "fuse-affine-relu",
        }
    }
}

/// One matched rewrite site.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub rule: Rule,
    pub matmul: usize,
    pub add_row: usize,
    pub relu: Option<usize>,
}

impl Candidate {
    pub fn describe(&self) -> String {
        match self.relu {
            Some(r) => format!(
                "%{} matmul + %{} add_row + %{r} relu -> affine(relu) [{}]",
                self.matmul,
                self.add_row,
                self.rule.name()
            ),
            None => format!(
                "%{} matmul + %{} add_row -> affine [{}]",
                self.matmul,
                self.add_row,
                self.rule.name()
            ),
        }
    }
}

/// Find every fusable chain in `prog`.
pub fn find(prog: &Program) -> Vec<Candidate> {
    let uses = prog.use_counts();
    let n = prog.nodes.len();
    let mut out = Vec::new();
    for j in 0..n {
        let OpIr::AddRow(m, _) = &prog.nodes[j].op else { continue };
        let m = *m;
        if !matches!(prog.nodes[m].op, OpIr::MatMul(..)) || uses[m] != 1 {
            continue;
        }
        // Extend over a trailing relu when the add_row's one user is one.
        let mut relu = None;
        if uses[j] == 1 {
            if let Some(r) =
                (j + 1..n).find(|&r| prog.nodes[r].op.operands().contains(&j))
            {
                if matches!(prog.nodes[r].op, OpIr::Relu(_)) {
                    relu = Some(r);
                }
            }
        }
        let rule = if relu.is_some() { Rule::FuseAffineRelu } else { Rule::FuseAffine };
        out.push(Candidate { rule, matmul: m, add_row: j, relu });
    }
    out
}

/// Apply one candidate, producing a new program with the chain collapsed
/// into a single `Affine` node at the chain tail's position (preserving
/// topological order) and every other operand index remapped.
pub fn apply(prog: &Program, cand: &Candidate) -> Program {
    let tail = cand.relu.unwrap_or(cand.add_row);
    let (x, w) = match &prog.nodes[cand.matmul].op {
        OpIr::MatMul(a, b) => (*a, *b),
        other => unreachable!("candidate matmul slot holds {}", other.name()),
    };
    let bias = match &prog.nodes[cand.add_row].op {
        OpIr::AddRow(_, b) => *b,
        other => unreachable!("candidate add_row slot holds {}", other.name()),
    };
    let mut map = vec![usize::MAX; prog.nodes.len()];
    let mut nodes = Vec::with_capacity(prog.nodes.len());
    for (i, n) in prog.nodes.iter().enumerate() {
        if i == tail {
            map[i] = nodes.len();
            nodes.push(NodeIr {
                op: OpIr::Affine {
                    x: map[x],
                    w: map[w],
                    b: map[bias],
                    relu: cand.relu.is_some(),
                },
                rows: n.rows,
                cols: n.cols,
                requires_grad: n.requires_grad,
            });
            continue;
        }
        if i == cand.matmul || i == cand.add_row {
            continue; // interior chain nodes are absorbed by the Affine
        }
        map[i] = nodes.len();
        nodes.push(NodeIr {
            op: remap_op(&n.op, &map),
            rows: n.rows,
            cols: n.cols,
            requires_grad: n.requires_grad,
        });
    }
    Program { nodes }
}

fn remap_op(op: &OpIr, map: &[usize]) -> OpIr {
    match op {
        OpIr::Leaf => OpIr::Leaf,
        OpIr::MatMul(a, b) => OpIr::MatMul(map[*a], map[*b]),
        OpIr::Add(a, b) => OpIr::Add(map[*a], map[*b]),
        OpIr::Sub(a, b) => OpIr::Sub(map[*a], map[*b]),
        OpIr::Mul(a, b) => OpIr::Mul(map[*a], map[*b]),
        OpIr::Relu(a) => OpIr::Relu(map[*a]),
        OpIr::Sigmoid(a) => OpIr::Sigmoid(map[*a]),
        OpIr::Tanh(a) => OpIr::Tanh(map[*a]),
        OpIr::GatherRows { x, idx } => OpIr::GatherRows { x: map[*x], idx: idx.clone() },
        OpIr::MeanAll(a) => OpIr::MeanAll(map[*a]),
        OpIr::MseLoss { diff } => OpIr::MseLoss { diff: map[*diff] },
        OpIr::BceLoss { logits, labels } => {
            OpIr::BceLoss { logits: map[*logits], labels: labels.clone() }
        }
        OpIr::AddRow(a, b) => OpIr::AddRow(map[*a], map[*b]),
        OpIr::Affine { x, w, b, relu } => {
            OpIr::Affine { x: map[*x], w: map[*w], b: map[*b], relu: *relu }
        }
        OpIr::ConcatCols(parts) => {
            OpIr::ConcatCols(parts.iter().map(|p| map[*p]).collect())
        }
        OpIr::Scale(a, c) => OpIr::Scale(map[*a], *c),
        OpIr::MatMulNT(a, b) => OpIr::MatMulNT(map[*a], map[*b]),
        OpIr::LayerNorm { x, eps } => OpIr::LayerNorm { x: map[*x], eps: *eps },
        OpIr::CausalAttn { q, k, v, seqs } => {
            OpIr::CausalAttn { q: map[*q], k: map[*k], v: map[*v], seqs: *seqs }
        }
        OpIr::SoftmaxXent { logits, targets } => {
            OpIr::SoftmaxXent { logits: map[*logits], targets: targets.clone() }
        }
    }
}

/// The admission rule: prove `rewritten` bit-identical to `orig` on the
/// given leaves across formats × backends × thread counts.  Returns the
/// number of (format, backend, threads) cells checked.
pub fn validate(
    orig: &Program,
    rewritten: &Program,
    leaves: &[Tensor],
) -> Result<u64, String> {
    let fmts = [FP32, BF16, FP16, E8M5];
    let combos =
        [(Backend::Fast, 1), (Backend::Fast, 4), (Backend::Reference, 1), (Backend::Simd, 1)];
    let mut checks = 0u64;
    for fmt in fmts {
        for (backend, threads) in combos {
            let cell = format!("{} {} t{threads}", fmt.name, backend.name());
            let policy = QPolicy::with_backend(fmt, backend);
            let a = exec::run(orig, leaves, policy, threads)
                .map_err(|e| format!("original replay failed [{cell}]: {e}"))?;
            let b = exec::run(rewritten, leaves, policy, threads)
                .map_err(|e| format!("rewritten replay failed [{cell}]: {e}"))?;
            if a.loss.to_bits() != b.loss.to_bits() {
                return Err(format!(
                    "loss differs [{cell}]: {:e} vs {:e}",
                    a.loss, b.loss
                ));
            }
            let (va, vb) = (a.values.last().unwrap(), b.values.last().unwrap());
            if !exec::bits_equal(va, vb) {
                return Err(format!("final forward value differs [{cell}]"));
            }
            let ga = leaf_grads(orig, &a);
            let gb = leaf_grads(rewritten, &b);
            for (k, (x, y)) in ga.iter().zip(&gb).enumerate() {
                match (x, y) {
                    (None, None) => {}
                    (Some(x), Some(y)) if exec::bits_equal(x, y) => {}
                    _ => {
                        return Err(format!("gradient of leaf #{k} differs [{cell}]"))
                    }
                }
            }
            checks += 1;
        }
    }
    Ok(checks)
}

/// Leaf gradients in leaf order (index-stable across the rewrite, which
/// never adds or removes leaves).
fn leaf_grads(prog: &Program, r: &exec::Replay) -> Vec<Option<Tensor>> {
    prog.leaf_nodes().into_iter().map(|i| r.grads[i].clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::super::lint::lint;
    use super::*;

    fn leaf(rows: usize, cols: usize, rg: bool) -> NodeIr {
        NodeIr { op: OpIr::Leaf, rows, cols, requires_grad: rg }
    }

    fn node(op: OpIr, rows: usize, cols: usize) -> NodeIr {
        NodeIr { op, rows, cols, requires_grad: true }
    }

    fn chain_program(with_relu: bool) -> (Program, Vec<Tensor>) {
        let mut nodes = vec![
            leaf(3, 2, false),
            leaf(2, 4, true),
            leaf(1, 4, true),
            node(OpIr::MatMul(0, 1), 3, 4),
            node(OpIr::AddRow(3, 2), 3, 4),
        ];
        let mut tail = 4;
        if with_relu {
            nodes.push(node(OpIr::Relu(4), 3, 4));
            tail = 5;
        }
        nodes.push(node(OpIr::MeanAll(tail), 1, 1));
        let leaves = vec![
            Tensor::from_vec(3, 2, vec![0.9, -1.4, 0.3, 2.0, -0.6, 0.1]),
            Tensor::from_vec(2, 4, vec![0.5, -0.2, 1.1, 0.7, -0.9, 0.4, 0.2, -1.3]),
            Tensor::from_vec(1, 4, vec![0.05, -0.3, 0.8, -0.01]),
        ];
        (Program { nodes }, leaves)
    }

    #[test]
    fn finds_and_fuses_the_relu_chain() {
        let (prog, leaves) = chain_program(true);
        let cands = find(&prog);
        assert_eq!(cands.len(), 1);
        assert_eq!(cands[0].rule, Rule::FuseAffineRelu);

        let rw = apply(&prog, &cands[0]);
        assert_eq!(rw.nodes.len(), prog.nodes.len() - 2);
        let root = rw.nodes.len() - 1;
        assert!(lint(&rw, root).errors().is_empty(), "{rw}");
        assert!(
            rw.nodes.iter().any(|n| matches!(n.op, OpIr::Affine { relu: true, .. })),
            "{rw}"
        );
        validate(&prog, &rw, &leaves).expect("fused chain must be bit-identical");
    }

    #[test]
    fn fuses_bias_only_chain_without_relu() {
        let (prog, leaves) = chain_program(false);
        let cands = find(&prog);
        assert_eq!(cands.len(), 1);
        assert_eq!(cands[0].rule, Rule::FuseAffine);
        let rw = apply(&prog, &cands[0]);
        assert!(
            rw.nodes.iter().any(|n| matches!(n.op, OpIr::Affine { relu: false, .. })),
            "{rw}"
        );
        validate(&prog, &rw, &leaves).expect("bias-fold must be bit-identical");
    }

    #[test]
    fn multi_use_matmul_is_not_a_candidate() {
        // The matmul output feeds both the add_row and a second consumer:
        // fusing it would erase a value the sigmoid still needs.
        let prog = Program {
            nodes: vec![
                leaf(2, 2, true),
                leaf(2, 3, true),
                leaf(1, 3, true),
                node(OpIr::MatMul(0, 1), 2, 3),
                node(OpIr::AddRow(3, 2), 2, 3),
                node(OpIr::Sigmoid(3), 2, 3),
                node(OpIr::Add(4, 5), 2, 3),
                node(OpIr::MeanAll(6), 1, 1),
            ],
        };
        assert!(find(&prog).is_empty());
    }

    #[test]
    fn multi_use_add_row_fuses_without_the_relu() {
        // add_row feeds a relu AND a second consumer: only the bias fold
        // is sound, the relu must stay a separate node.
        let prog = Program {
            nodes: vec![
                leaf(2, 2, true),
                leaf(2, 3, true),
                leaf(1, 3, true),
                node(OpIr::MatMul(0, 1), 2, 3),
                node(OpIr::AddRow(3, 2), 2, 3),
                node(OpIr::Relu(4), 2, 3),
                node(OpIr::Add(4, 5), 2, 3),
                node(OpIr::MeanAll(6), 1, 1),
            ],
        };
        let cands = find(&prog);
        assert_eq!(cands.len(), 1);
        assert_eq!(cands[0].rule, Rule::FuseAffine);
        assert_eq!(cands[0].relu, None);
        let rw = apply(&prog, &cands[0]);
        let root = rw.nodes.len() - 1;
        assert!(lint(&rw, root).errors().is_empty(), "{rw}");
    }
}
