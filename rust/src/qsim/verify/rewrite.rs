//! Pattern-driven rewrite engine over tape programs, with bit-identity
//! admission.
//!
//! PR 6 shipped this pass with two hard-coded matchers (`matmul +
//! add_row (+ relu)` → [`Affine`](OpIr::Affine)).  It is now a general
//! engine driven by a ruleset: a [`Rule`] is a pair of op [`Pattern`]s
//! over pattern variables (`(relu (add_row (matmul ?a ?b) ?c)) =>
//! (affine_relu ?a ?b ?c)`), and the engine matches any rule's left-hand
//! side anywhere in a program and splices in the right-hand side.  The
//! shipped ruleset is *synthesized* by [`super::synth`] (enumerate →
//! cvec-cluster → bit-prove) and checked in at
//! `rust/tests/data/synth_rules.txt`; [`admitted_ruleset`] embeds that
//! corpus at compile time.
//!
//! Soundness preconditions are static:
//!
//! - every *interior* node of a match (an op node matched below the lhs
//!   root) must be single-use — rewriting a multi-use node would drop a
//!   value other nodes read;
//! - a pattern variable occurring twice only matches when both positions
//!   bind the *same* node (`(add ?a ?a)` matches `add(%3, %3)` only);
//! - admitted rules are strictly shrinking (lhs has more op nodes than
//!   rhs), so [`rewrite_fixpoint`] terminates.
//!
//! A matched rewrite is only *admitted* when [`validate`] proves the
//! rewritten program bit-identical to the original — loss, every leaf
//! gradient, and the final forward value — across
//! {fast, reference, simd} × {1, 4} intra-threads × the format sweep.
//! [`validate_rule`] runs the same sweep on a rule in isolation (fresh
//! seeded valuations of its pattern variables); the synthesizer admits
//! through it, `cargo test` and `repro synth-rules --check` re-prove the
//! corpus through it, and the fuzzer re-proves the ruleset end-to-end on
//! every generated program.

use std::collections::HashSet;
use std::fmt;
use std::sync::OnceLock;

use super::exec;
use super::ir::{NodeIr, OpIr, Program};
use crate::precision::{BF16, E8M5, FP16, FP32};
use crate::qsim::{Backend, QPolicy, Tensor};
use crate::util::rng::Rng;

/// The checked-in synthesized ruleset (regenerate with
/// `repro synth-rules --write`).
const CORPUS: &str = include_str!("../../../tests/data/synth_rules.txt");

// ---------------------------------------------------------------------------
// Pattern vocabulary
// ---------------------------------------------------------------------------

/// Ops a pattern can range over: the payload-free tape vocabulary, plus
/// `scale` / `layernorm` whose constants are part of the pattern and must
/// match bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PatOp {
    MatMul,
    MatMulNT,
    Add,
    Sub,
    Mul,
    Relu,
    Sigmoid,
    Tanh,
    AddRow,
    Affine { relu: bool },
    Scale(f32),
    LayerNorm(f32),
    MeanAll,
}

impl PatOp {
    pub fn name(&self) -> &'static str {
        match self {
            PatOp::MatMul => "matmul",
            PatOp::MatMulNT => "matmul_nt",
            PatOp::Add => "add",
            PatOp::Sub => "sub",
            PatOp::Mul => "mul",
            PatOp::Relu => "relu",
            PatOp::Sigmoid => "sigmoid",
            PatOp::Tanh => "tanh",
            PatOp::AddRow => "add_row",
            PatOp::Affine { relu: false } => "affine",
            PatOp::Affine { relu: true } => "affine_relu",
            PatOp::Scale(_) => "scale",
            PatOp::LayerNorm(_) => "layernorm",
            PatOp::MeanAll => "mean_all",
        }
    }

    pub fn arity(&self) -> usize {
        match self {
            PatOp::Relu
            | PatOp::Sigmoid
            | PatOp::Tanh
            | PatOp::Scale(_)
            | PatOp::LayerNorm(_)
            | PatOp::MeanAll => 1,
            PatOp::MatMul
            | PatOp::MatMulNT
            | PatOp::Add
            | PatOp::Sub
            | PatOp::Mul
            | PatOp::AddRow => 2,
            PatOp::Affine { .. } => 3,
        }
    }

    /// If `op` is an instance of this pattern op (constants compared by
    /// bit pattern), its operand node indices.
    fn match_op(&self, op: &OpIr) -> Option<Vec<usize>> {
        match (self, op) {
            (PatOp::MatMul, OpIr::MatMul(a, b))
            | (PatOp::MatMulNT, OpIr::MatMulNT(a, b))
            | (PatOp::Add, OpIr::Add(a, b))
            | (PatOp::Sub, OpIr::Sub(a, b))
            | (PatOp::Mul, OpIr::Mul(a, b))
            | (PatOp::AddRow, OpIr::AddRow(a, b)) => Some(vec![*a, *b]),
            (PatOp::Relu, OpIr::Relu(a))
            | (PatOp::Sigmoid, OpIr::Sigmoid(a))
            | (PatOp::Tanh, OpIr::Tanh(a))
            | (PatOp::MeanAll, OpIr::MeanAll(a)) => Some(vec![*a]),
            (PatOp::Scale(c), OpIr::Scale(a, k)) if c.to_bits() == k.to_bits() => {
                Some(vec![*a])
            }
            (PatOp::LayerNorm(e), OpIr::LayerNorm { x, eps })
                if e.to_bits() == eps.to_bits() =>
            {
                Some(vec![*x])
            }
            (PatOp::Affine { relu }, OpIr::Affine { x, w, b, relu: r }) if relu == r => {
                Some(vec![*x, *w, *b])
            }
            _ => None,
        }
    }

    /// The concrete op over the given operand node indices.
    fn build(&self, k: &[usize]) -> OpIr {
        match self {
            PatOp::MatMul => OpIr::MatMul(k[0], k[1]),
            PatOp::MatMulNT => OpIr::MatMulNT(k[0], k[1]),
            PatOp::Add => OpIr::Add(k[0], k[1]),
            PatOp::Sub => OpIr::Sub(k[0], k[1]),
            PatOp::Mul => OpIr::Mul(k[0], k[1]),
            PatOp::AddRow => OpIr::AddRow(k[0], k[1]),
            PatOp::Relu => OpIr::Relu(k[0]),
            PatOp::Sigmoid => OpIr::Sigmoid(k[0]),
            PatOp::Tanh => OpIr::Tanh(k[0]),
            PatOp::MeanAll => OpIr::MeanAll(k[0]),
            PatOp::Scale(c) => OpIr::Scale(k[0], *c),
            PatOp::LayerNorm(e) => OpIr::LayerNorm { x: k[0], eps: *e },
            PatOp::Affine { relu } => {
                OpIr::Affine { x: k[0], w: k[1], b: k[2], relu: *relu }
            }
        }
    }

    /// Output shape from operand shapes, or `None` on a type error.
    pub fn infer_shape(&self, s: &[(usize, usize)]) -> Option<(usize, usize)> {
        match self {
            PatOp::MatMul => (s[0].1 == s[1].0).then_some((s[0].0, s[1].1)),
            PatOp::MatMulNT => (s[0].1 == s[1].1).then_some((s[0].0, s[1].0)),
            PatOp::Add | PatOp::Sub | PatOp::Mul => (s[0] == s[1]).then_some(s[0]),
            PatOp::AddRow => (s[1] == (1, s[0].1)).then_some(s[0]),
            PatOp::Relu
            | PatOp::Sigmoid
            | PatOp::Tanh
            | PatOp::Scale(_)
            | PatOp::LayerNorm(_) => Some(s[0]),
            PatOp::MeanAll => Some((1, 1)),
            PatOp::Affine { .. } => {
                (s[0].1 == s[1].0 && s[2] == (1, s[1].1)).then_some((s[0].0, s[1].1))
            }
        }
    }

    fn parse(name: &str, consts: &[f32]) -> Result<PatOp, String> {
        let want = |n: usize| {
            if consts.len() == n {
                Ok(())
            } else {
                Err(format!("op {name} takes {n} constant(s), got {}", consts.len()))
            }
        };
        match name {
            "matmul" => want(0).map(|_| PatOp::MatMul),
            "matmul_nt" => want(0).map(|_| PatOp::MatMulNT),
            "add" => want(0).map(|_| PatOp::Add),
            "sub" => want(0).map(|_| PatOp::Sub),
            "mul" => want(0).map(|_| PatOp::Mul),
            "relu" => want(0).map(|_| PatOp::Relu),
            "sigmoid" => want(0).map(|_| PatOp::Sigmoid),
            "tanh" => want(0).map(|_| PatOp::Tanh),
            "add_row" => want(0).map(|_| PatOp::AddRow),
            "affine" => want(0).map(|_| PatOp::Affine { relu: false }),
            "affine_relu" => want(0).map(|_| PatOp::Affine { relu: true }),
            "mean_all" => want(0).map(|_| PatOp::MeanAll),
            "scale" => want(1).map(|_| PatOp::Scale(consts[0])),
            "layernorm" => want(1).map(|_| PatOp::LayerNorm(consts[0])),
            other => Err(format!("unknown pattern op '{other}'")),
        }
    }

    fn consts(&self) -> Vec<f32> {
        match self {
            PatOp::Scale(c) | PatOp::LayerNorm(c) => vec![*c],
            _ => vec![],
        }
    }
}

/// A pattern term: a variable or an op over sub-patterns.
#[derive(Debug, Clone, PartialEq)]
pub enum Pattern {
    Var(usize),
    Op(PatOp, Vec<Pattern>),
}

impl Pattern {
    /// Number of op nodes (variables are free).
    pub fn op_count(&self) -> usize {
        match self {
            Pattern::Var(_) => 0,
            Pattern::Op(_, kids) => 1 + kids.iter().map(Pattern::op_count).sum::<usize>(),
        }
    }

    /// Sorted, deduplicated variable indices.
    pub fn vars(&self) -> Vec<usize> {
        let mut v = Vec::new();
        self.collect_vars(&mut v);
        v.sort_unstable();
        v.dedup();
        v
    }

    fn collect_vars(&self, out: &mut Vec<usize>) {
        match self {
            Pattern::Var(v) => out.push(*v),
            Pattern::Op(_, kids) => kids.iter().for_each(|k| k.collect_vars(out)),
        }
    }

    /// Variables in first-occurrence (left-to-right) order.
    pub fn vars_in_order(&self) -> Vec<usize> {
        let mut v = Vec::new();
        self.collect_vars(&mut v);
        let mut seen = HashSet::new();
        v.retain(|x| seen.insert(*x));
        v
    }

    /// Rename variables via `map[old] = new`.
    pub fn rename_vars(&self, map: &[usize]) -> Pattern {
        match self {
            Pattern::Var(v) => Pattern::Var(map[*v]),
            Pattern::Op(op, kids) => {
                Pattern::Op(*op, kids.iter().map(|k| k.rename_vars(map)).collect())
            }
        }
    }

    /// Output shape given per-variable shapes, or `None` on a type error.
    pub fn infer_shape(&self, var_shapes: &[(usize, usize)]) -> Option<(usize, usize)> {
        match self {
            Pattern::Var(v) => var_shapes.get(*v).copied(),
            Pattern::Op(op, kids) => {
                let ks: Option<Vec<_>> =
                    kids.iter().map(|k| k.infer_shape(var_shapes)).collect();
                op.infer_shape(&ks?)
            }
        }
    }

    /// Parse a s-expression like `(relu (add_row (matmul ?a ?b) ?c))`.
    pub fn parse(s: &str) -> Result<Pattern, String> {
        let toks = tokenize(s);
        let mut pos = 0usize;
        let pat = parse_sexpr(&toks, &mut pos)?;
        if pos != toks.len() {
            return Err(format!("trailing tokens after pattern in '{s}'"));
        }
        Ok(pat)
    }
}

impl fmt::Display for Pattern {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Pattern::Var(v) => write!(f, "?{}", var_letter(*v)),
            Pattern::Op(op, kids) => {
                write!(f, "({}", op.name())?;
                for k in kids {
                    write!(f, " {k}")?;
                }
                for c in op.consts() {
                    write!(f, " {c}")?;
                }
                write!(f, ")")
            }
        }
    }
}

fn var_letter(v: usize) -> char {
    (b'a' + (v as u8) % 26) as char
}

fn tokenize(s: &str) -> Vec<String> {
    let mut toks = Vec::new();
    let mut cur = String::new();
    for ch in s.chars() {
        match ch {
            '(' | ')' => {
                if !cur.is_empty() {
                    toks.push(std::mem::take(&mut cur));
                }
                toks.push(ch.to_string());
            }
            c if c.is_whitespace() => {
                if !cur.is_empty() {
                    toks.push(std::mem::take(&mut cur));
                }
            }
            c => cur.push(c),
        }
    }
    if !cur.is_empty() {
        toks.push(cur);
    }
    toks
}

fn parse_sexpr(toks: &[String], pos: &mut usize) -> Result<Pattern, String> {
    let Some(t) = toks.get(*pos) else {
        return Err("unexpected end of pattern".into());
    };
    *pos += 1;
    if let Some(v) = t.strip_prefix('?') {
        let c = v.chars().next().ok_or("empty variable name")?;
        if v.len() != 1 || !c.is_ascii_lowercase() {
            return Err(format!("variable '?{v}' must be a single letter a-z"));
        }
        return Ok(Pattern::Var((c as u8 - b'a') as usize));
    }
    if t != "(" {
        return Err(format!("expected '(' or variable, got '{t}'"));
    }
    let name = toks.get(*pos).ok_or("missing op name")?.clone();
    *pos += 1;
    let mut kids = Vec::new();
    let mut consts = Vec::new();
    loop {
        let Some(t) = toks.get(*pos) else {
            return Err("unclosed '(' in pattern".into());
        };
        if t == ")" {
            *pos += 1;
            break;
        }
        // A bare number atom is an op constant, anything else a sub-pattern.
        if t != "(" && !t.starts_with('?') {
            let c: f32 = t
                .parse()
                .map_err(|_| format!("bad constant '{t}' in pattern op {name}"))?;
            consts.push(c);
            *pos += 1;
            continue;
        }
        kids.push(parse_sexpr(toks, pos)?);
    }
    let op = PatOp::parse(&name, &consts)?;
    if op.arity() != kids.len() {
        return Err(format!(
            "op {name} takes {} operand(s), got {}",
            op.arity(),
            kids.len()
        ));
    }
    Ok(Pattern::Op(op, kids))
}

// ---------------------------------------------------------------------------
// Rules and the corpus
// ---------------------------------------------------------------------------

/// One admitted rewrite rule: `lhs => rhs` over shared pattern variables,
/// with the witness shapes its admission proof ran at.
#[derive(Debug, Clone, PartialEq)]
pub struct Rule {
    pub name: String,
    pub lhs: Pattern,
    pub rhs: Pattern,
    /// Shape of each pattern variable `0..n` in the admission proof.
    /// Matching is shape-agnostic; the proof is at these witnesses (and
    /// re-proven by the fuzzer on every program the ruleset fires in).
    pub shapes: Vec<(usize, usize)>,
}

impl Rule {
    /// Structural well-formedness: same non-empty variable set on both
    /// sides, every variable witnessed, both sides type-check to the same
    /// root shape, and the rule strictly shrinks.
    pub fn check(&self) -> Result<(), String> {
        let (lv, rv) = (self.lhs.vars(), self.rhs.vars());
        if lv.is_empty() {
            return Err(format!("rule {}: lhs has no variables", self.name));
        }
        if lv != rv {
            return Err(format!("rule {}: lhs/rhs variable sets differ", self.name));
        }
        if lv != (0..self.shapes.len()).collect::<Vec<_>>() {
            return Err(format!(
                "rule {}: variables must be dense 0..{} matching the witness shapes",
                self.name,
                self.shapes.len()
            ));
        }
        if self.lhs.op_count() <= self.rhs.op_count() {
            return Err(format!(
                "rule {}: not strictly shrinking ({} -> {} ops)",
                self.name,
                self.lhs.op_count(),
                self.rhs.op_count()
            ));
        }
        let ls = self.lhs.infer_shape(&self.shapes);
        let rs = self.rhs.infer_shape(&self.shapes);
        match (ls, rs) {
            (Some(a), Some(b)) if a == b => Ok(()),
            (Some(a), Some(b)) => Err(format!(
                "rule {}: sides disagree on root shape ({}x{} vs {}x{})",
                self.name, a.0, a.1, b.0, b.1
            )),
            _ => Err(format!("rule {}: a side fails shape inference", self.name)),
        }
    }

    /// One corpus line: `name: lhs => rhs ; a=RxC b=RxC ...`
    pub fn render(&self) -> String {
        let shapes = self
            .shapes
            .iter()
            .enumerate()
            .map(|(v, (r, c))| format!("{}={r}x{c}", var_letter(v)))
            .collect::<Vec<_>>()
            .join(" ");
        format!("{}: {} => {} ; {}", self.name, self.lhs, self.rhs, shapes)
    }

    pub fn parse(line: &str) -> Result<Rule, String> {
        let (name, rest) =
            line.split_once(':').ok_or_else(|| format!("missing rule name: '{line}'"))?;
        let (body, shapes_s) =
            rest.split_once(';').ok_or_else(|| format!("missing witness shapes: '{line}'"))?;
        let (lhs_s, rhs_s) =
            body.split_once("=>").ok_or_else(|| format!("missing '=>': '{line}'"))?;
        let lhs = Pattern::parse(lhs_s.trim())?;
        let rhs = Pattern::parse(rhs_s.trim())?;
        let mut shapes: Vec<Option<(usize, usize)>> = Vec::new();
        for part in shapes_s.split_whitespace() {
            let (v, sh) = part
                .split_once('=')
                .ok_or_else(|| format!("bad shape entry '{part}'"))?;
            let c = v.chars().next().ok_or("empty shape variable")?;
            let vi = (c as u8).wrapping_sub(b'a') as usize;
            let (r, cc) =
                sh.split_once('x').ok_or_else(|| format!("bad shape '{sh}'"))?;
            let dim = |s: &str| {
                s.parse::<usize>().map_err(|_| format!("bad dimension '{s}' in '{part}'"))
            };
            if shapes.len() <= vi {
                shapes.resize(vi + 1, None);
            }
            shapes[vi] = Some((dim(r)?, dim(cc)?));
        }
        let shapes: Vec<(usize, usize)> = shapes
            .into_iter()
            .enumerate()
            .map(|(v, s)| s.ok_or(format!("missing shape for ?{}", var_letter(v))))
            .collect::<Result<_, _>>()?;
        let rule = Rule { name: name.trim().to_string(), lhs, rhs, shapes };
        rule.check()?;
        Ok(rule)
    }
}

/// The parsed checked-in corpus: the synthesis coordinates it was grown
/// at plus every admitted rule.
#[derive(Debug, Clone)]
pub struct CorpusDoc {
    pub depth: usize,
    pub seed: u64,
    pub rules: Vec<Rule>,
}

impl CorpusDoc {
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(
            "# Synthesized tape rewrite ruleset (qsim::verify::synth).\n\
             # Every rule is bit-proven: loss, forward root and every leaf gradient\n\
             # identical across {fp32,bf16,fp16,e8m5} x {fast,reference,simd} x {1,4}\n\
             # intra-threads at the witness shapes, re-proven by `cargo test` and\n\
             # continuously by `repro fuzz-tape` on generated programs.\n\
             #\n\
             # This file is the *pinned* subset of what synthesis admits: rules the\n\
             # fuzzer is allowed to apply to arbitrary generated programs.  `repro\n\
             # synth-rules --check` fails if any pinned rule stops proving or stops\n\
             # being synthesized; newly admitted rules are listed for review and land\n\
             # here via `repro synth-rules --write` once vetted.\n",
        );
        out.push_str(&format!("@synth depth={} seed={}\n", self.depth, self.seed));
        for r in &self.rules {
            out.push_str(&r.render());
            out.push('\n');
        }
        out
    }

    pub fn parse(text: &str) -> Result<CorpusDoc, String> {
        let mut doc = CorpusDoc { depth: 0, seed: 0, rules: Vec::new() };
        let mut saw_header = false;
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(hdr) = line.strip_prefix("@synth") {
                for kv in hdr.split_whitespace() {
                    match kv.split_once('=') {
                        Some(("depth", d)) => {
                            doc.depth =
                                d.parse().map_err(|_| format!("bad depth '{d}'"))?
                        }
                        Some(("seed", s)) => {
                            doc.seed = s.parse().map_err(|_| format!("bad seed '{s}'"))?
                        }
                        _ => return Err(format!("bad @synth entry '{kv}'")),
                    }
                }
                saw_header = true;
                continue;
            }
            doc.rules.push(Rule::parse(line)?);
        }
        if !saw_header {
            return Err("corpus is missing its '@synth depth=.. seed=..' header".into());
        }
        let mut names: Vec<&str> = doc.rules.iter().map(|r| r.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        if names.len() != doc.rules.len() {
            return Err("duplicate rule names in corpus".into());
        }
        Ok(doc)
    }
}

/// The checked-in corpus, parsed once.  Panics only if the embedded
/// `tests/data/synth_rules.txt` is malformed, which `cargo test` and the
/// `qsim-synth` CI job both gate.
pub fn admitted_ruleset() -> &'static [Rule] {
    static RULES: OnceLock<Vec<Rule>> = OnceLock::new();
    RULES.get_or_init(|| {
        let mut doc = CorpusDoc::parse(CORPUS)
            .unwrap_or_else(|e| panic!("embedded synth_rules.txt corpus is invalid: {e}"));
        // Match priority: biggest lhs first, so the classic three-node
        // chain collapses in one step instead of two.
        doc.rules.sort_by(|a, b| {
            b.lhs.op_count().cmp(&a.lhs.op_count()).then(a.name.cmp(&b.name))
        });
        doc.rules
    })
}

/// The embedded corpus, unsorted, with its synthesis coordinates.
pub fn corpus_doc() -> Result<CorpusDoc, String> {
    CorpusDoc::parse(CORPUS)
}

// ---------------------------------------------------------------------------
// Matching and application
// ---------------------------------------------------------------------------

/// One matched rewrite site: `rule` (index into the ruleset passed to
/// [`find`]) matched with its lhs root at node `root`, pattern variables
/// bound to `bindings` (by variable index).
#[derive(Debug, Clone)]
pub struct Found {
    pub rule: usize,
    pub root: usize,
    pub bindings: Vec<usize>,
}

impl Found {
    pub fn describe(&self, rules: &[Rule]) -> String {
        format!("rule {} matches at %{}", rules[self.rule].name, self.root)
    }
}

/// Every sound match of any rule in `prog`, scanning nodes in program
/// order and rules in ruleset order (deterministic).
pub fn find(prog: &Program, rules: &[Rule]) -> Vec<Found> {
    let uses = prog.use_counts();
    let mut out = Vec::new();
    for root in 0..prog.nodes.len() {
        for (ri, rule) in rules.iter().enumerate() {
            if let Some(bindings) = match_rule(prog, &uses, rule, root) {
                out.push(Found { rule: ri, root, bindings });
            }
        }
    }
    out
}

/// Try to match `rule.lhs` with its root at `root`.  Returns the
/// variable bindings on success.
fn match_rule(
    prog: &Program,
    uses: &[usize],
    rule: &Rule,
    root: usize,
) -> Option<Vec<usize>> {
    let mut bind: Vec<Option<usize>> = vec![None; rule.shapes.len()];
    let mut interior = Vec::new();
    if !match_pattern(prog, &rule.lhs, root, &mut bind, &mut interior, true) {
        return None;
    }
    // Static interference analysis: interior nodes (matched op nodes below
    // the root) are deleted by the rewrite, so each must be single-use.
    if interior.iter().any(|&n| uses[n] != 1) {
        return None;
    }
    bind.into_iter().collect()
}

fn match_pattern(
    prog: &Program,
    pat: &Pattern,
    node: usize,
    bind: &mut Vec<Option<usize>>,
    interior: &mut Vec<usize>,
    is_root: bool,
) -> bool {
    match pat {
        Pattern::Var(v) => match bind[*v] {
            Some(b) => b == node,
            None => {
                bind[*v] = Some(node);
                true
            }
        },
        Pattern::Op(op, kids) => {
            let Some(operands) = op.match_op(&prog.nodes[node].op) else {
                return false;
            };
            if !is_root {
                interior.push(node);
            }
            operands.len() == kids.len()
                && kids
                    .iter()
                    .zip(&operands)
                    .all(|(k, &o)| match_pattern(prog, k, o, bind, interior, false))
        }
    }
}

/// Apply one match: delete the lhs interior, splice the rhs tree in at
/// the root's position (preserving topological order), remap every other
/// operand index.
pub fn apply(prog: &Program, rule: &Rule, f: &Found) -> Program {
    let mut bind: Vec<Option<usize>> = vec![None; rule.shapes.len()];
    let mut interior = Vec::new();
    let ok = match_pattern(prog, &rule.lhs, f.root, &mut bind, &mut interior, true);
    debug_assert!(ok, "apply called with a stale match");
    let removed: HashSet<usize> = interior.into_iter().collect();

    let mut map = vec![usize::MAX; prog.nodes.len()];
    let mut nodes: Vec<NodeIr> = Vec::with_capacity(prog.nodes.len());
    for (i, n) in prog.nodes.iter().enumerate() {
        if i == f.root {
            map[i] = emit_rhs(&rule.rhs, &f.bindings, &map, &mut nodes);
            debug_assert_eq!(
                (nodes[map[i]].rows, nodes[map[i]].cols),
                (n.rows, n.cols),
                "rhs root shape drifts from the node it replaces"
            );
            continue;
        }
        if removed.contains(&i) {
            continue;
        }
        map[i] = nodes.len();
        nodes.push(NodeIr {
            op: remap_op(&n.op, &map),
            rows: n.rows,
            cols: n.cols,
            requires_grad: n.requires_grad,
        });
    }
    Program { nodes }
}

/// Emit the rhs tree bottom-up, returning the new index of its root.  A
/// bare-variable rhs emits nothing and redirects to the bound node.
fn emit_rhs(
    pat: &Pattern,
    bindings: &[usize],
    map: &[usize],
    nodes: &mut Vec<NodeIr>,
) -> usize {
    match pat {
        Pattern::Var(v) => map[bindings[*v]],
        Pattern::Op(op, kids) => {
            let ks: Vec<usize> =
                kids.iter().map(|k| emit_rhs(k, bindings, map, nodes)).collect();
            let shapes: Vec<(usize, usize)> =
                ks.iter().map(|&k| (nodes[k].rows, nodes[k].cols)).collect();
            let (rows, cols) = op
                .infer_shape(&shapes)
                .expect("admitted rule rhs must type-check at matched shapes");
            nodes.push(NodeIr { op: op.build(&ks), rows, cols, requires_grad: true });
            nodes.len() - 1
        }
    }
}

fn remap_op(op: &OpIr, map: &[usize]) -> OpIr {
    match op {
        OpIr::Leaf => OpIr::Leaf,
        OpIr::MatMul(a, b) => OpIr::MatMul(map[*a], map[*b]),
        OpIr::Add(a, b) => OpIr::Add(map[*a], map[*b]),
        OpIr::Sub(a, b) => OpIr::Sub(map[*a], map[*b]),
        OpIr::Mul(a, b) => OpIr::Mul(map[*a], map[*b]),
        OpIr::Relu(a) => OpIr::Relu(map[*a]),
        OpIr::Sigmoid(a) => OpIr::Sigmoid(map[*a]),
        OpIr::Tanh(a) => OpIr::Tanh(map[*a]),
        OpIr::GatherRows { x, idx } => OpIr::GatherRows { x: map[*x], idx: idx.clone() },
        OpIr::MeanAll(a) => OpIr::MeanAll(map[*a]),
        OpIr::MseLoss { diff } => OpIr::MseLoss { diff: map[*diff] },
        OpIr::BceLoss { logits, labels } => {
            OpIr::BceLoss { logits: map[*logits], labels: labels.clone() }
        }
        OpIr::AddRow(a, b) => OpIr::AddRow(map[*a], map[*b]),
        OpIr::Affine { x, w, b, relu } => {
            OpIr::Affine { x: map[*x], w: map[*w], b: map[*b], relu: *relu }
        }
        OpIr::ConcatCols(parts) => {
            OpIr::ConcatCols(parts.iter().map(|p| map[*p]).collect())
        }
        OpIr::Scale(a, c) => OpIr::Scale(map[*a], *c),
        OpIr::MatMulNT(a, b) => OpIr::MatMulNT(map[*a], map[*b]),
        OpIr::LayerNorm { x, eps } => OpIr::LayerNorm { x: map[*x], eps: *eps },
        OpIr::CausalAttn { q, k, v, seqs } => {
            OpIr::CausalAttn { q: map[*q], k: map[*k], v: map[*v], seqs: *seqs }
        }
        OpIr::SoftmaxXent { logits, targets } => {
            OpIr::SoftmaxXent { logits: map[*logits], targets: targets.clone() }
        }
    }
}

/// Rewrite to fixpoint: repeatedly apply the first (deterministic) match
/// until none fire.  Terminates because every admitted rule strictly
/// shrinks the program.  Returns the rewritten program and the names of
/// the rules applied, in order.
pub fn rewrite_fixpoint(prog: &Program, rules: &[Rule]) -> (Program, Vec<String>) {
    let mut cur = prog.clone();
    let mut applied = Vec::new();
    loop {
        let found = find(&cur, rules);
        let Some(f) = found.first() else { break };
        applied.push(rules[f.rule].name.clone());
        cur = apply(&cur, &rules[f.rule], f);
    }
    (cur, applied)
}

// ---------------------------------------------------------------------------
// Bit-identity admission
// ---------------------------------------------------------------------------

/// The admission sweep cells: every backend at 1 and 4 intra-threads.
const ADMIT_COMBOS: [(Backend, usize); 6] = [
    (Backend::Fast, 1),
    (Backend::Fast, 4),
    (Backend::Reference, 1),
    (Backend::Reference, 4),
    (Backend::Simd, 1),
    (Backend::Simd, 4),
];

/// The admission rule: prove `rewritten` bit-identical to `orig` on the
/// given leaves across formats × backends × thread counts.  Returns the
/// number of (format, backend, threads) cells checked.
pub fn validate(
    orig: &Program,
    rewritten: &Program,
    leaves: &[Tensor],
) -> Result<u64, String> {
    let fmts = [FP32, BF16, FP16, E8M5];
    let mut checks = 0u64;
    for fmt in fmts {
        for (backend, threads) in ADMIT_COMBOS {
            let cell = format!("{} {} t{threads}", fmt.name, backend.name());
            let policy = QPolicy::with_backend(fmt, backend);
            let a = exec::run(orig, leaves, policy, threads)
                .map_err(|e| format!("original replay failed [{cell}]: {e}"))?;
            let b = exec::run(rewritten, leaves, policy, threads)
                .map_err(|e| format!("rewritten replay failed [{cell}]: {e}"))?;
            if a.loss.to_bits() != b.loss.to_bits() {
                return Err(format!(
                    "loss differs [{cell}]: {:e} vs {:e}",
                    a.loss, b.loss
                ));
            }
            let (va, vb) = (a.values.last().unwrap(), b.values.last().unwrap());
            if !exec::bits_equal(va, vb) {
                return Err(format!("final forward value differs [{cell}]"));
            }
            let ga = leaf_grads(orig, &a);
            let gb = leaf_grads(rewritten, &b);
            for (k, (x, y)) in ga.iter().zip(&gb).enumerate() {
                match (x, y) {
                    (None, None) => {}
                    (Some(x), Some(y)) if exec::bits_equal(x, y) => {}
                    _ => {
                        return Err(format!("gradient of leaf #{k} differs [{cell}]"))
                    }
                }
            }
            checks += 1;
        }
    }
    Ok(checks)
}

/// Build a rule side as a standalone program: one trainable leaf per
/// pattern variable (in variable order), then the op tree.
pub fn pattern_program(
    pat: &Pattern,
    shapes: &[(usize, usize)],
) -> Result<Program, String> {
    if matches!(pat, Pattern::Var(_)) {
        // The replayer roots at the *last* node, which for a leaf-only
        // program would be the wrong leaf — and no such rule can be
        // admitted anyway (leaves hold raw values, op outputs are
        // format-rounded, so an op tree is never bit-equal to a leaf).
        return Err("bare-variable pattern has no op root to validate".into());
    }
    let mut nodes: Vec<NodeIr> = shapes
        .iter()
        .map(|&(rows, cols)| NodeIr { op: OpIr::Leaf, rows, cols, requires_grad: true })
        .collect();
    fn emit(
        pat: &Pattern,
        shapes: &[(usize, usize)],
        nodes: &mut Vec<NodeIr>,
    ) -> Result<usize, String> {
        match pat {
            Pattern::Var(v) => {
                if *v >= shapes.len() {
                    return Err(format!("variable ?{} has no shape", var_letter(*v)));
                }
                Ok(*v)
            }
            Pattern::Op(op, kids) => {
                let ks: Vec<usize> = kids
                    .iter()
                    .map(|k| emit(k, shapes, nodes))
                    .collect::<Result<_, _>>()?;
                let kshapes: Vec<(usize, usize)> =
                    ks.iter().map(|&k| (nodes[k].rows, nodes[k].cols)).collect();
                let (rows, cols) = op.infer_shape(&kshapes).ok_or_else(|| {
                    format!("pattern {pat} fails shape inference at {}", op.name())
                })?;
                nodes.push(NodeIr { op: op.build(&ks), rows, cols, requires_grad: true });
                Ok(nodes.len() - 1)
            }
        }
    }
    emit(pat, shapes, &mut nodes)?;
    Ok(Program { nodes })
}

/// Seeded leaf tensors for one valuation of a rule's variables
/// (occasionally scaled up to poke the narrow formats, like the fuzzer's
/// leaf generator).
pub fn valuation_leaves(
    shapes: &[(usize, usize)],
    seed: u64,
    valuation: u64,
) -> Vec<Tensor> {
    shapes
        .iter()
        .enumerate()
        .map(|(v, &(rows, cols))| {
            let mut rng =
                Rng::new(seed ^ (v as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15), valuation);
            let scale = if rng.below(4) == 0 { 4.0 } else { 1.0 };
            let data = (0..rows * cols).map(|_| rng.normal() * scale).collect();
            Tensor::from_vec(rows, cols, data)
        })
        .collect()
}

/// Re-prove a rule's admission: both sides as standalone programs at the
/// witness shapes, `valuations` fresh seeded variable assignments, the
/// full [`validate`] sweep on each.  Returns cells checked.
pub fn validate_rule(rule: &Rule, seed: u64, valuations: usize) -> Result<u64, String> {
    rule.check()?;
    let lhs = pattern_program(&rule.lhs, &rule.shapes)?;
    let rhs = pattern_program(&rule.rhs, &rule.shapes)?;
    let mut cells = 0u64;
    for v in 0..valuations {
        let leaves = valuation_leaves(&rule.shapes, seed, v as u64);
        cells += validate(&lhs, &rhs, &leaves)
            .map_err(|e| format!("rule {} valuation {v}: {e}", rule.name))?;
    }
    Ok(cells)
}

/// Leaf gradients in leaf order (index-stable across the rewrite, which
/// never adds or removes leaves).
fn leaf_grads(prog: &Program, r: &exec::Replay) -> Vec<Option<Tensor>> {
    prog.leaf_nodes().into_iter().map(|i| r.grads[i].clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::super::lint::lint;
    use super::*;

    fn leaf(rows: usize, cols: usize, rg: bool) -> NodeIr {
        NodeIr { op: OpIr::Leaf, rows, cols, requires_grad: rg }
    }

    fn node(op: OpIr, rows: usize, cols: usize) -> NodeIr {
        NodeIr { op, rows, cols, requires_grad: true }
    }

    fn chain_program(with_relu: bool) -> (Program, Vec<Tensor>) {
        let mut nodes = vec![
            leaf(3, 2, false),
            leaf(2, 4, true),
            leaf(1, 4, true),
            node(OpIr::MatMul(0, 1), 3, 4),
            node(OpIr::AddRow(3, 2), 3, 4),
        ];
        let mut tail = 4;
        if with_relu {
            nodes.push(node(OpIr::Relu(4), 3, 4));
            tail = 5;
        }
        nodes.push(node(OpIr::MeanAll(tail), 1, 1));
        let leaves = vec![
            Tensor::from_vec(3, 2, vec![0.9, -1.4, 0.3, 2.0, -0.6, 0.1]),
            Tensor::from_vec(2, 4, vec![0.5, -0.2, 1.1, 0.7, -0.9, 0.4, 0.2, -1.3]),
            Tensor::from_vec(1, 4, vec![0.05, -0.3, 0.8, -0.01]),
        ];
        (Program { nodes }, leaves)
    }

    #[test]
    fn pattern_parse_roundtrips() {
        for s in [
            "(relu (add_row (matmul ?a ?b) ?c))",
            "(affine_relu ?a ?b ?c)",
            "(scale ?a 2)",
            "(mean_all (mean_all ?a))",
            "(add ?a ?a)",
        ] {
            let p = Pattern::parse(s).unwrap();
            assert_eq!(p.to_string(), s);
        }
        assert!(Pattern::parse("(bogus ?a)").is_err());
        assert!(Pattern::parse("(relu ?a ?b)").is_err());
        assert!(Pattern::parse("(scale ?a)").is_err());
    }

    #[test]
    fn rule_line_roundtrips_and_checks() {
        let line = "fuse-affine: (add_row (matmul ?a ?b) ?c) => (affine ?a ?b ?c) ; a=3x4 b=4x2 c=1x2";
        let r = Rule::parse(line).unwrap();
        assert_eq!(r.render(), line);
        // Growing rules are rejected.
        assert!(Rule::parse(
            "grow: (relu ?a) => (relu (relu ?a)) ; a=2x2"
        )
        .is_err());
        // Variable-set mismatch is rejected.
        assert!(Rule::parse(
            "drop: (mul ?a ?b) => (relu ?a) ; a=2x2 b=2x2"
        )
        .is_err());
    }

    #[test]
    fn embedded_corpus_parses_and_contains_the_pr6_rules() {
        let rules = admitted_ruleset();
        assert!(rules.iter().any(|r| r.name == "fuse-affine"));
        assert!(rules.iter().any(|r| r.name == "fuse-affine-relu"));
        for r in rules {
            r.check().unwrap();
        }
    }

    #[test]
    fn finds_and_fuses_the_relu_chain_in_one_step() {
        let (prog, leaves) = chain_program(true);
        let rules = admitted_ruleset();
        let (rw, applied) = rewrite_fixpoint(&prog, rules);
        assert!(
            applied.contains(&"fuse-affine-relu".to_string()),
            "applied: {applied:?}"
        );
        let root = rw.nodes.len() - 1;
        assert!(lint(&rw, root).errors().is_empty(), "{rw}");
        assert!(
            rw.nodes.iter().any(|n| matches!(n.op, OpIr::Affine { relu: true, .. })),
            "{rw}"
        );
        validate(&prog, &rw, &leaves).expect("fused chain must be bit-identical");
    }

    #[test]
    fn fuses_bias_only_chain_without_relu() {
        let (prog, leaves) = chain_program(false);
        let (rw, applied) = rewrite_fixpoint(&prog, admitted_ruleset());
        assert!(applied.contains(&"fuse-affine".to_string()), "applied: {applied:?}");
        assert!(
            rw.nodes.iter().any(|n| matches!(n.op, OpIr::Affine { relu: false, .. })),
            "{rw}"
        );
        validate(&prog, &rw, &leaves).expect("bias-fold must be bit-identical");
    }

    #[test]
    fn multi_use_matmul_is_not_a_candidate() {
        // The matmul output feeds both the add_row and a second consumer:
        // fusing it would erase a value the sigmoid still needs.
        let prog = Program {
            nodes: vec![
                leaf(2, 2, true),
                leaf(2, 3, true),
                leaf(1, 3, true),
                node(OpIr::MatMul(0, 1), 2, 3),
                node(OpIr::AddRow(3, 2), 2, 3),
                node(OpIr::Sigmoid(3), 2, 3),
                node(OpIr::Add(4, 5), 2, 3),
                node(OpIr::MeanAll(6), 1, 1),
            ],
        };
        let fuse: Vec<Rule> = admitted_ruleset()
            .iter()
            .filter(|r| r.name.starts_with("fuse-affine"))
            .cloned()
            .collect();
        assert!(find(&prog, &fuse).is_empty());
    }

    #[test]
    fn multi_use_add_row_fuses_without_the_relu() {
        // add_row feeds a relu AND a second consumer: only the bias fold
        // is sound, the relu must stay a separate node.
        let prog = Program {
            nodes: vec![
                leaf(2, 2, true),
                leaf(2, 3, true),
                leaf(1, 3, true),
                node(OpIr::MatMul(0, 1), 2, 3),
                node(OpIr::AddRow(3, 2), 2, 3),
                node(OpIr::Relu(4), 2, 3),
                node(OpIr::Add(4, 5), 2, 3),
                node(OpIr::MeanAll(6), 1, 1),
            ],
        };
        let fuse: Vec<Rule> = admitted_ruleset()
            .iter()
            .filter(|r| r.name.starts_with("fuse-affine"))
            .cloned()
            .collect();
        let found = find(&prog, &fuse);
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(fuse[found[0].rule].name, "fuse-affine");
        let rw = apply(&prog, &fuse[found[0].rule], &found[0]);
        let root = rw.nodes.len() - 1;
        assert!(lint(&rw, root).errors().is_empty(), "{rw}");
        assert!(rw.nodes.iter().any(|n| matches!(n.op, OpIr::Relu(_))), "{rw}");
    }

    #[test]
    fn repeated_variable_only_binds_one_node() {
        let rule = Rule::parse("double: (add ?a ?a) => (scale ?a 2) ; a=2x2").unwrap();
        // add(%1, %1) matches; add(%1, %2) must not.
        let same = Program {
            nodes: vec![
                leaf(2, 2, true),
                node(OpIr::Relu(0), 2, 2),
                node(OpIr::Add(1, 1), 2, 2),
                node(OpIr::MeanAll(2), 1, 1),
            ],
        };
        let diff = Program {
            nodes: vec![
                leaf(2, 2, true),
                leaf(2, 2, true),
                node(OpIr::Add(0, 1), 2, 2),
                node(OpIr::MeanAll(2), 1, 1),
            ],
        };
        let rules = [rule];
        assert_eq!(find(&same, &rules).len(), 1);
        assert!(find(&diff, &rules).is_empty());
        let f = &find(&same, &rules)[0];
        let rw = apply(&same, &rules[f.rule], f);
        assert!(rw.nodes.iter().any(|n| matches!(n.op, OpIr::Scale(_, c) if c == 2.0)));
        assert!(lint(&rw, rw.nodes.len() - 1).errors().is_empty(), "{rw}");
    }

    #[test]
    fn bare_variable_rhs_redirects_users() {
        // Not admissible numerically (a raw leaf is not rounded like an op
        // output), but the splice mechanics must handle a Var rhs: the
        // root's users are redirected to the bound node.
        let rule = Rule {
            name: "erase".into(),
            lhs: Pattern::parse("(relu (relu ?a))").unwrap(),
            rhs: Pattern::Var(0),
            shapes: vec![(2, 2)],
        };
        rule.check().unwrap();
        let prog = Program {
            nodes: vec![
                leaf(2, 2, true),
                node(OpIr::Relu(0), 2, 2),
                node(OpIr::Relu(1), 2, 2),
                node(OpIr::MeanAll(2), 1, 1),
            ],
        };
        let rules = [rule];
        let (rw, applied) = rewrite_fixpoint(&prog, &rules);
        assert_eq!(applied, vec!["erase".to_string()]);
        assert_eq!(rw.nodes.len(), 2);
        assert!(matches!(rw.nodes[1].op, OpIr::MeanAll(0)), "{rw}");
        assert!(lint(&rw, 1).errors().is_empty(), "{rw}");
    }

    #[test]
    fn validate_rule_reproves_the_pr6_rules_on_fresh_valuations() {
        for name in ["fuse-affine", "fuse-affine-relu"] {
            let rule = admitted_ruleset().iter().find(|r| r.name == name).unwrap();
            let cells = validate_rule(rule, 0xD1CE, 2).expect(name);
            assert!(cells > 0);
        }
    }

    #[test]
    fn validate_rule_rejects_a_numerically_false_rule() {
        // Distributivity holds in the reals but not under per-op rounding
        // (a*b + a*c rounds three times, a*(b+c) rounds twice and in a
        // different order) — exactly the kind of plausible candidate the
        // admission sweep exists to reject.
        let rule = Rule {
            name: "unsound-distribute".into(),
            lhs: Pattern::parse("(add (mul ?a ?b) (mul ?a ?c))").unwrap(),
            rhs: Pattern::parse("(mul ?a (add ?b ?c))").unwrap(),
            shapes: vec![(2, 3), (2, 3), (2, 3)],
        };
        rule.check().unwrap();
        assert!(validate_rule(&rule, 7, 3).is_err());
    }
}
