//! Program IR over recorded tape graphs.
//!
//! A [`Program`] is a flat, append-only list of [`NodeIr`] nodes mirroring
//! the tape's `Op` list one-to-one: node `i` of the IR is tape node `i`,
//! operands are plain indices (always `< i`), and constant payloads
//! (gather indices, xent targets, BCE labels, the scale factor) are baked
//! into the op so a program is self-contained — it can be linted, printed,
//! replayed on a fresh tape ([`super::exec`]), and rewritten
//! ([`super::rewrite`]) without touching the tape that produced it.

use std::fmt;

/// One tape operation, operands by node index.
#[derive(Debug, Clone, PartialEq)]
pub enum OpIr {
    /// Leaf (input or parameter — distinguished by `NodeIr::requires_grad`).
    Leaf,
    MatMul(usize, usize),
    Add(usize, usize),
    Sub(usize, usize),
    Mul(usize, usize),
    Relu(usize),
    Sigmoid(usize),
    Tanh(usize),
    /// Row gather (`Op::Embed` exports as this): out[r] = x[idx[r]].
    GatherRows { x: usize, idx: Vec<usize> },
    MeanAll(usize),
    /// Fused `0.5 * mean(d^2)` over a difference node (replayable
    /// standalone via `Tape::mse_of`).
    MseLoss { diff: usize },
    BceLoss { logits: usize, labels: Vec<f32> },
    AddRow(usize, usize),
    /// Fused `x @ w + b` (+ optional relu) — the validated rewrite target.
    Affine { x: usize, w: usize, b: usize, relu: bool },
    ConcatCols(Vec<usize>),
    Scale(usize, f32),
    MatMulNT(usize, usize),
    LayerNorm { x: usize, eps: f32 },
    CausalAttn { q: usize, k: usize, v: usize, seqs: usize },
    SoftmaxXent { logits: usize, targets: Vec<usize> },
}

impl OpIr {
    pub fn name(&self) -> &'static str {
        match self {
            OpIr::Leaf => "leaf",
            OpIr::MatMul(..) => "matmul",
            OpIr::Add(..) => "add",
            OpIr::Sub(..) => "sub",
            OpIr::Mul(..) => "mul",
            OpIr::Relu(..) => "relu",
            OpIr::Sigmoid(..) => "sigmoid",
            OpIr::Tanh(..) => "tanh",
            OpIr::GatherRows { .. } => "gather_rows",
            OpIr::MeanAll(..) => "mean_all",
            OpIr::MseLoss { .. } => "mse_loss",
            OpIr::BceLoss { .. } => "bce_loss",
            OpIr::AddRow(..) => "add_row",
            OpIr::Affine { .. } => "affine",
            OpIr::ConcatCols(..) => "concat_cols",
            OpIr::Scale(..) => "scale",
            OpIr::MatMulNT(..) => "matmul_nt",
            OpIr::LayerNorm { .. } => "layernorm",
            OpIr::CausalAttn { .. } => "causal_attn",
            OpIr::SoftmaxXent { .. } => "softmax_xent",
        }
    }

    /// Whether [`super::exec::run`] can rebuild this op on a fresh tape
    /// from the exported payload alone.  Every op must stay replayable —
    /// a non-replayable export silently shrinks the fuzzer's and the
    /// synthesizer's reachable pattern space, so the linter reports any
    /// such node as an error.  (MseLoss was the one historical offender,
    /// fixed by `Tape::mse_of`.)
    pub fn replayable(&self) -> bool {
        true
    }

    /// Operand node indices, in the order backward visits them.
    pub fn operands(&self) -> Vec<usize> {
        match self {
            OpIr::Leaf => vec![],
            OpIr::MatMul(a, b)
            | OpIr::Add(a, b)
            | OpIr::Sub(a, b)
            | OpIr::Mul(a, b)
            | OpIr::AddRow(a, b)
            | OpIr::MatMulNT(a, b) => vec![*a, *b],
            OpIr::Relu(a)
            | OpIr::Sigmoid(a)
            | OpIr::Tanh(a)
            | OpIr::MeanAll(a)
            | OpIr::Scale(a, _) => vec![*a],
            OpIr::GatherRows { x, .. } => vec![*x],
            OpIr::MseLoss { diff } => vec![*diff],
            OpIr::BceLoss { logits, .. } => vec![*logits],
            OpIr::Affine { x, w, b, .. } => vec![*x, *w, *b],
            OpIr::ConcatCols(parts) => parts.clone(),
            OpIr::LayerNorm { x, .. } => vec![*x],
            OpIr::CausalAttn { q, k, v, .. } => vec![*q, *k, *v],
            OpIr::SoftmaxXent { logits, .. } => vec![*logits],
        }
    }
}

/// One IR node: the op plus the shape and grad flag the tape recorded.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeIr {
    pub op: OpIr,
    pub rows: usize,
    pub cols: usize,
    pub requires_grad: bool,
}

/// A whole tape program (see module docs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Program {
    pub nodes: Vec<NodeIr>,
}

impl Program {
    /// How many nodes reference each node as an operand.
    pub fn use_counts(&self) -> Vec<usize> {
        let mut uses = vec![0usize; self.nodes.len()];
        for n in &self.nodes {
            for d in n.op.operands() {
                if d < uses.len() {
                    uses[d] += 1;
                }
            }
        }
        uses
    }

    /// Nodes reachable from `root` by following operands.
    pub fn reachable(&self, root: usize) -> Vec<bool> {
        let mut seen = vec![false; self.nodes.len()];
        if root >= self.nodes.len() {
            return seen;
        }
        let mut stack = vec![root];
        while let Some(i) = stack.pop() {
            if seen[i] {
                continue;
            }
            seen[i] = true;
            for d in self.nodes[i].op.operands() {
                if d < self.nodes.len() && !seen[d] {
                    stack.push(d);
                }
            }
        }
        seen
    }

    /// Node indices of all leaves, in leaf (replay-feed) order.
    pub fn leaf_nodes(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n.op, OpIr::Leaf))
            .map(|(i, _)| i)
            .collect()
    }
}

impl fmt::Display for Program {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, n) in self.nodes.iter().enumerate() {
            write!(f, "%{i:<3} = {}", n.op.name())?;
            let ops = n.op.operands();
            if !ops.is_empty() {
                write!(f, "(")?;
                for (k, d) in ops.iter().enumerate() {
                    if k > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "%{d}")?;
                }
                write!(f, ")")?;
            }
            match &n.op {
                OpIr::Scale(_, c) => write!(f, " c={c}")?,
                OpIr::LayerNorm { eps, .. } => write!(f, " eps={eps}")?,
                OpIr::CausalAttn { seqs, .. } => write!(f, " seqs={seqs}")?,
                OpIr::GatherRows { idx, .. } => write!(f, " idx={idx:?}")?,
                OpIr::SoftmaxXent { targets, .. } => write!(f, " targets={targets:?}")?,
                OpIr::BceLoss { labels, .. } => write!(f, " labels[{}]", labels.len())?,
                OpIr::Affine { relu, .. } => write!(f, " relu={relu}")?,
                _ => {}
            }
            write!(f, "  [{}x{}]", n.rows, n.cols)?;
            if n.requires_grad {
                write!(f, " grad")?;
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leaf(rows: usize, cols: usize, rg: bool) -> NodeIr {
        NodeIr { op: OpIr::Leaf, rows, cols, requires_grad: rg }
    }

    #[test]
    fn use_counts_and_reachability() {
        let prog = Program {
            nodes: vec![
                leaf(2, 3, false),
                leaf(3, 2, true),
                NodeIr { op: OpIr::MatMul(0, 1), rows: 2, cols: 2, requires_grad: true },
                leaf(2, 2, true), // dead
                NodeIr { op: OpIr::MeanAll(2), rows: 1, cols: 1, requires_grad: true },
            ],
        };
        assert_eq!(prog.use_counts(), vec![1, 1, 1, 0, 0]);
        let seen = prog.reachable(4);
        assert_eq!(seen, vec![true, true, true, false, true]);
        assert_eq!(prog.leaf_nodes(), vec![0, 1, 3]);
    }

    #[test]
    fn display_lists_every_node() {
        let prog = Program {
            nodes: vec![
                leaf(1, 2, true),
                NodeIr { op: OpIr::Relu(0), rows: 1, cols: 2, requires_grad: true },
            ],
        };
        let s = prog.to_string();
        assert!(s.contains("relu(%0)"), "{s}");
        assert_eq!(s.lines().count(), 2);
    }
}
