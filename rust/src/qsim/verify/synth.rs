//! Ruler-style enumerative rewrite-rule synthesis over the tape IR.
//!
//! The pipeline (after `ruler`/`enumo`, adapted to a *bitwise* equivalence
//! relation instead of a semantic one):
//!
//! 1. **Enumerate** — grow all small op patterns over the
//!    [`PatOp`](rewrite::PatOp) vocabulary from a seeded variable workload
//!    ([`VAR_SHAPES`]), level `k` holding terms with exactly `k` op nodes,
//!    up to `--depth`.  Growth is *representative-based*: a term whose
//!    cvec collides with an earlier term joins that cluster but is not
//!    grown further (any rule through it is reachable via the
//!    representative).  The classic `matmul + add_row (+ relu)` chain is
//!    seeded eagerly (when an `add_row(matmul(..), _)` term is built its
//!    relu-wrapped form is emitted at the same level), mirroring the
//!    fuzzer generator's chain bias.
//! 2. **cvec fingerprint** — evaluate every term on shared seeded input
//!    vectors (the same leaf data for variable `v` in every term) across
//!    both backends (fast, reference) and the compute-format sweep
//!    (fp32 / bf16 / fp16 / e8m5), forward *and* leaf gradients, and
//!    fingerprint the bit patterns.  Terms whose fingerprints collide
//!    bit-for-bit cluster together.
//! 3. **Candidates** — each non-trivial cluster proposes rules
//!    `lhs => rhs` with the smallest member as rhs.  Only strictly
//!    shrinking candidates with equal variable sets survive (a bare
//!    variable can never be a side: leaves carry raw values, op outputs
//!    are rounded onto the compute format, so no op tree is bit-equal to
//!    a leaf).
//! 4. **Derivability filter** — a candidate whose lhs already rewrites to
//!    its rhs under the rules admitted so far proves nothing new (it is
//!    an *instance* of smaller rules, like
//!    `add_row(matmul(relu ?a) ?b) ?c → affine(relu ?a) ?b ?c` once the
//!    general bias fold is in) and is skipped, Ruler-fashion.  The two
//!    historical hot-path rules (`fuse-affine`, `fuse-affine-relu`) are
//!    exempt: they stay pinned explicitly even though the smaller folds
//!    compose to subsume the three-node chain, because match priority
//!    (biggest lhs first) wants the one-step collapse.
//! 5. **Admit** — every surviving candidate goes through
//!    [`rewrite::validate_rule`]: *fresh* seeded valuations (a different
//!    stream than the cvecs), and bit-identity of loss, root forward and
//!    every leaf gradient across {fp32, bf16, fp16, e8m5} ×
//!    {fast, reference, simd} × {1, 4} intra-threads.
//!
//! The admitted ruleset is versioned at `rust/tests/data/synth_rules.txt`
//! (`repro synth-rules --write` regenerates it; `--check` re-proves every
//! checked-in rule *and* re-synthesizes, failing if any pinned rule is no
//! longer admitted) and drives the generalized [`rewrite`](super::rewrite)
//! engine; the fuzzer re-proves it on every generated program.
//!
//! Caps are never silent: per-level truncation (deterministic stride
//! sampling over the sorted candidate list, so the survivors stay
//! diverse) and the admitted-rule cap are both reported in
//! [`SynthReport`].

use std::collections::{BTreeMap, HashMap, HashSet};

use super::exec;
use super::rewrite::{self, PatOp, Pattern, Rule};
use crate::precision::{BF16, E8M5, FP16, FP32};
use crate::qsim::{Backend, QPolicy};

/// The seeded variable workload: pattern variables `?a..?e` with the
/// shapes every enumerated term is typed (and every cvec evaluated) at.
/// Two same-shaped activations, a weight, a bias row and a thin row
/// vector cover every operand role the vocabulary has.
pub const VAR_SHAPES: [(usize, usize); 5] = [(3, 4), (3, 4), (4, 2), (1, 2), (1, 4)];

/// Scale constants the enumerator ranges over.
const SCALE_CONSTS: [f32; 3] = [2.0, 0.5, -1.0];

/// The one layernorm epsilon every app records.
const LN_EPS: f32 = 1e-5;

#[derive(Debug, Clone)]
pub struct SynthConfig {
    /// Maximum pattern size in op nodes (the PR-6 relu chain is 3; its
    /// chain-bias seeding makes it reachable from depth 2).
    pub depth: usize,
    /// Seed for the shared cvec valuations and the (derived, distinct)
    /// admission valuations.
    pub seed: u64,
    /// Per-level term cap; overflow is stride-sampled and reported.
    pub max_terms_per_level: usize,
    /// Seeded valuations per cvec fingerprint.
    pub cvec_valuations: usize,
    /// Fresh seeded valuations per admission proof.
    pub admit_valuations: usize,
    /// Largest-lhs candidates taken per cluster (reported when exceeded).
    pub max_lhs_per_cluster: usize,
    /// Admitted-ruleset cap (reported when hit).
    pub max_rules: usize,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            depth: 3,
            seed: 7,
            // Level 2 of the default workload holds ~2.5k well-typed
            // terms; the cap must clear it so every size-2 lhs is
            // enumerated, and only the (much larger) deeper levels get
            // stride-sampled.
            max_terms_per_level: 4000,
            cvec_valuations: 3,
            admit_valuations: 3,
            max_lhs_per_cluster: 4,
            max_rules: 24,
        }
    }
}

impl SynthConfig {
    pub fn at(depth: usize, seed: u64) -> Self {
        SynthConfig { depth, seed, ..SynthConfig::default() }
    }
}

/// Everything one synthesis run observed, caps included.
#[derive(Debug, Clone)]
pub struct SynthReport {
    pub depth: usize,
    pub seed: u64,
    /// Terms enumerated and cvec-evaluated.
    pub enumerated: usize,
    /// Terms dropped by the per-level cap (deterministic stride sample).
    pub dropped: usize,
    /// Terms whose cvec evaluation failed (skipped, not clustered).
    pub eval_failed: usize,
    /// Clusters with at least two members.
    pub clusters: usize,
    /// Candidate rules extracted from clusters (post dedup).
    pub candidates: usize,
    /// Candidates dropped by `max_lhs_per_cluster` / `max_rules`.
    pub capped: usize,
    /// Renders of candidates skipped because the already-admitted rules
    /// rewrite their lhs to their rhs (instances of smaller rules).
    pub derived: Vec<String>,
    /// Rules that survived the bit-identity admission sweep.
    pub admitted: Vec<Rule>,
    /// `(rule, first divergence)` for every rejected candidate.
    pub rejected: Vec<(String, String)>,
    /// Total (format × backend × threads × valuation) admission cells.
    pub admission_cells: u64,
}

impl SynthReport {
    /// The corpus document this run produces.
    pub fn corpus(&self) -> rewrite::CorpusDoc {
        rewrite::CorpusDoc {
            depth: self.depth,
            seed: self.seed,
            rules: self.admitted.clone(),
        }
    }
}

/// The admission valuations must be fresh relative to the cvec stream —
/// a candidate must survive data it was not clustered on.
pub fn admission_seed(seed: u64) -> u64 {
    seed ^ 0xAD31_55ED
}

struct Term {
    pat: Pattern,
    /// Op-node count (true size; chain-bias terms exceed their intro level).
    size: usize,
    shape: (usize, usize),
    key: String,
}

/// Run the full enumerate → cvec-cluster → admit pipeline.
pub fn synthesize(cfg: &SynthConfig) -> SynthReport {
    let mut report = SynthReport {
        depth: cfg.depth,
        seed: cfg.seed,
        enumerated: 0,
        dropped: 0,
        eval_failed: 0,
        clusters: 0,
        candidates: 0,
        capped: 0,
        derived: Vec::new(),
        admitted: Vec::new(),
        rejected: Vec::new(),
        admission_cells: 0,
    };

    let var_shapes: Vec<(usize, usize)> = VAR_SHAPES.to_vec();
    let mut terms: Vec<Term> = Vec::new();
    let mut seen: HashSet<String> = HashSet::new();
    // fingerprint -> term ids, insertion-ordered; BTreeMap for determinism.
    let mut clusters: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    // Term ids that grow at the next levels (cluster representatives).
    let mut reps: Vec<usize> = Vec::new();

    // Level 0: the variables themselves (growth seeds, never clustered —
    // no admissible rule can have a bare-variable side, see module docs).
    for (v, &shape) in var_shapes.iter().enumerate() {
        let pat = Pattern::Var(v);
        let key = pat.to_string();
        seen.insert(key.clone());
        terms.push(Term { pat, size: 0, shape, key });
        reps.push(terms.len() - 1);
    }

    // Pre-compute the shared cvec valuations once.
    let valuations: Vec<Vec<crate::qsim::Tensor>> = (0..cfg.cvec_valuations)
        .map(|v| rewrite::valuation_leaves(&var_shapes, cfg.seed, v as u64))
        .collect();

    // Hard generation valve: pattern counts explode combinatorially with
    // depth, so a level stops *generating* (not just sampling) well above
    // the keep cap.  Seeded chain terms bypass it — they are the workload.
    let valve = cfg.max_terms_per_level.saturating_mul(50);

    for level in 1..=cfg.depth {
        let mut cands: Vec<(Pattern, usize, (usize, usize), bool)> = Vec::new();
        let mut valve_dropped = 0usize;
        let push_cand =
            |cands: &mut Vec<(Pattern, usize, (usize, usize), bool)>,
             seen: &mut HashSet<String>,
             valve_dropped: &mut usize,
             pat: Pattern,
             size: usize,
             shape: (usize, usize),
             seeded: bool| {
                let key = pat.to_string();
                if seen.insert(key) {
                    if seeded || cands.len() < valve {
                        cands.push((pat, size, shape, seeded));
                    } else {
                        *valve_dropped += 1;
                    }
                }
            };

        // Unary ops over size-(level-1) representatives.
        let unary: Vec<PatOp> = {
            let mut u = vec![
                PatOp::Relu,
                PatOp::Sigmoid,
                PatOp::Tanh,
                PatOp::MeanAll,
                PatOp::LayerNorm(LN_EPS),
            ];
            u.extend(SCALE_CONSTS.iter().map(|&c| PatOp::Scale(c)));
            u
        };
        for &t in &reps {
            if terms[t].size != level - 1 {
                continue;
            }
            for op in &unary {
                if let Some(shape) = op.infer_shape(&[terms[t].shape]) {
                    let pat = Pattern::Op(*op, vec![terms[t].pat.clone()]);
                    push_cand(
                        &mut cands,
                        &mut seen,
                        &mut valve_dropped,
                        pat,
                        level,
                        shape,
                        false,
                    );
                }
            }
        }

        // Binary ops over representative pairs with sizes summing level-1.
        let binary =
            [PatOp::Add, PatOp::Sub, PatOp::Mul, PatOp::MatMul, PatOp::MatMulNT, PatOp::AddRow];
        for &t1 in &reps {
            for &t2 in &reps {
                if terms[t1].size + terms[t2].size != level - 1 {
                    continue;
                }
                for op in &binary {
                    let Some(shape) = op.infer_shape(&[terms[t1].shape, terms[t2].shape])
                    else {
                        continue;
                    };
                    let pat = Pattern::Op(
                        *op,
                        vec![terms[t1].pat.clone(), terms[t2].pat.clone()],
                    );
                    // Chain-bias seeding: the classic fusable chain gets its
                    // relu-wrapped form at the same level (size level+1), so
                    // depth-2 synthesis already sees the PR-6 relu chain.
                    let bias = *op == PatOp::AddRow
                        && matches!(&terms[t1].pat, Pattern::Op(PatOp::MatMul, _));
                    if bias {
                        let wrapped = Pattern::Op(PatOp::Relu, vec![pat.clone()]);
                        push_cand(
                            &mut cands,
                            &mut seen,
                            &mut valve_dropped,
                            wrapped,
                            level + 1,
                            shape,
                            true,
                        );
                    }
                    push_cand(&mut cands, &mut seen, &mut valve_dropped, pat, level, shape, bias);
                }
            }
        }

        // Affine (3-ary): x ranges over representatives, w/b over variables
        // (pattern matching is structural, so variable operands already
        // generalize to arbitrary subgraphs at match time).
        for &tx in &reps {
            if terms[tx].size != level - 1 {
                continue;
            }
            for w in 0..var_shapes.len() {
                for b in 0..var_shapes.len() {
                    for relu in [false, true] {
                        let op = PatOp::Affine { relu };
                        let Some(shape) = op.infer_shape(&[
                            terms[tx].shape,
                            var_shapes[w],
                            var_shapes[b],
                        ]) else {
                            continue;
                        };
                        let pat = Pattern::Op(
                            op,
                            vec![
                                terms[tx].pat.clone(),
                                Pattern::Var(w),
                                Pattern::Var(b),
                            ],
                        );
                        push_cand(
                            &mut cands,
                            &mut seen,
                            &mut valve_dropped,
                            pat,
                            level,
                            shape,
                            false,
                        );
                    }
                }
            }
        }

        // Deterministic order, then a deterministic stride sample if the
        // level overflows its cap (keeps the survivors spread over the
        // whole op alphabet instead of whatever sorts first).  Seeded
        // chain terms always survive — they are the workload.
        report.dropped += valve_dropped;
        cands.sort_by(|a, b| a.1.cmp(&b.1).then_with(|| a.0.to_string().cmp(&b.0.to_string())));
        let kept: Vec<(Pattern, usize, (usize, usize), bool)> =
            if cands.len() > cfg.max_terms_per_level {
                let total = cands.len();
                let stride = total.div_ceil(cfg.max_terms_per_level);
                let sampled: Vec<_> = cands
                    .into_iter()
                    .enumerate()
                    .filter(|(i, c)| c.3 || i % stride == 0)
                    .map(|(_, c)| c)
                    .collect();
                report.dropped += total - sampled.len();
                sampled
            } else {
                cands
            };

        // cvec-evaluate and cluster; first member of a new cluster becomes
        // a growth representative.
        for (pat, size, shape, _) in kept {
            report.enumerated += 1;
            let key = pat.to_string();
            let id = terms.len();
            let fp = fingerprint(&pat, &var_shapes, &valuations);
            terms.push(Term { pat, size, shape, key });
            match fp {
                None => report.eval_failed += 1,
                Some(fp) => {
                    let members = clusters.entry(fp).or_default();
                    if members.is_empty() {
                        reps.push(id);
                    }
                    members.push(id);
                }
            }
        }
    }

    // Candidate extraction: smallest member rewrites to, larger members
    // rewrite from.  Clusters are visited in *enumeration* order (their
    // earliest member's term id), not fingerprint order, so which
    // witness-shape instance of a rule wins the cross-cluster dedup below
    // is stable and predictable (the earliest-enumerated variables).
    let mut groups: Vec<Vec<usize>> =
        clusters.into_values().filter(|m| m.len() >= 2).collect();
    groups.sort_by_key(|m| m[0]);
    let mut cand_rules: Vec<Rule> = Vec::new();
    let mut names: HashMap<String, usize> = HashMap::new();
    for members in &groups {
        report.clusters += 1;
        let mut sorted = members.clone();
        sorted.sort_by(|&a, &b| {
            terms[a].size.cmp(&terms[b].size).then_with(|| terms[a].key.cmp(&terms[b].key))
        });
        let rhs_id = sorted[0];
        let mut taken = 0usize;
        for &lhs_id in &sorted[1..] {
            if terms[lhs_id].size <= terms[rhs_id].size
                || terms[lhs_id].pat.vars() != terms[rhs_id].pat.vars()
            {
                continue;
            }
            if taken >= cfg.max_lhs_per_cluster {
                report.capped += 1;
                continue;
            }
            taken += 1;
            // Renumber variables densely by lhs first-occurrence order and
            // record the witness shapes.
            let order = terms[lhs_id].pat.vars_in_order();
            let mut map = vec![usize::MAX; var_shapes.len()];
            for (new, &old) in order.iter().enumerate() {
                map[old] = new;
            }
            let lhs = terms[lhs_id].pat.rename_vars(&map);
            let rhs = terms[rhs_id].pat.rename_vars(&map);
            let shapes: Vec<(usize, usize)> =
                order.iter().map(|&v| var_shapes[v]).collect();
            if cand_rules.iter().any(|r| r.lhs == lhs && r.rhs == rhs) {
                continue; // same rule from another witness-shape cluster
            }
            let base = rule_name(&lhs, &rhs);
            let n = names.entry(base.clone()).or_insert(0);
            *n += 1;
            let name = if *n == 1 { base } else { format!("{base}-{n}") };
            let rule = Rule { name, lhs, rhs, shapes };
            if rule.check().is_ok() {
                cand_rules.push(rule);
            }
        }
    }
    cand_rules.sort_by(|a, b| {
        a.lhs.op_count().cmp(&b.lhs.op_count()).then_with(|| a.name.cmp(&b.name))
    });
    report.candidates = cand_rules.len();

    // Admission: smallest lhs first, so the derivability filter sees the
    // general rules before their instances; then the hardened PR-6
    // validator on fresh valuations.
    let admit_seed = admission_seed(cfg.seed);
    for rule in cand_rules {
        if report.admitted.len() >= cfg.max_rules {
            report.capped += 1;
            continue;
        }
        let pinned = matches!(rule.name.as_str(), "fuse-affine" | "fuse-affine-relu");
        if !pinned && derivable(&rule, &report.admitted) {
            report.derived.push(rule.render());
            continue;
        }
        match rewrite::validate_rule(&rule, admit_seed, cfg.admit_valuations) {
            Ok(cells) => {
                report.admission_cells += cells;
                report.admitted.push(rule);
            }
            Err(e) => report.rejected.push((rule.render(), e)),
        }
    }
    report
}

/// Ruler's redundancy filter: a candidate is *derived* when rewriting its
/// lhs program to fixpoint under the already-admitted rules yields
/// exactly its rhs program — it is an instance of smaller proven rules
/// and admitting it would only bloat the corpus.
fn derivable(rule: &Rule, admitted: &[Rule]) -> bool {
    let (Ok(lhs), Ok(rhs)) = (
        rewrite::pattern_program(&rule.lhs, &rule.shapes),
        rewrite::pattern_program(&rule.rhs, &rule.shapes),
    ) else {
        return false;
    };
    let (rw, applied) = rewrite::rewrite_fixpoint(&lhs, admitted);
    !applied.is_empty() && rw == rhs
}

/// Bitwise characteristic vector of `pat`, folded to a 64-bit FNV-1a
/// fingerprint: root shape, then for every (valuation × format × backend)
/// cell the loss bits, the root forward bits and every per-variable leaf
/// gradient (presence plus bits).  `None` when any cell fails to replay.
fn fingerprint(
    pat: &Pattern,
    var_shapes: &[(usize, usize)],
    valuations: &[Vec<crate::qsim::Tensor>],
) -> Option<u64> {
    let prog = rewrite::pattern_program(pat, var_shapes).ok()?;
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    let eat = |h: &mut u64, bytes: &[u8]| {
        for &b in bytes {
            *h ^= b as u64;
            *h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    };
    let root = prog.nodes.len() - 1;
    eat(&mut h, &(prog.nodes[root].rows as u64).to_le_bytes());
    eat(&mut h, &(prog.nodes[root].cols as u64).to_le_bytes());
    for leaves in valuations {
        for fmt in [FP32, BF16, FP16, E8M5] {
            for backend in [Backend::Fast, Backend::Reference] {
                let rep =
                    exec::run(&prog, leaves, QPolicy::with_backend(fmt, backend), 1).ok()?;
                eat(&mut h, &rep.loss.to_bits().to_le_bytes());
                for x in &rep.values[root].data {
                    eat(&mut h, &x.to_bits().to_le_bytes());
                }
                for v in 0..var_shapes.len() {
                    match &rep.grads[v] {
                        None => eat(&mut h, &[0xFF]),
                        Some(g) => {
                            eat(&mut h, &[0x01]);
                            for x in &g.data {
                                eat(&mut h, &x.to_bits().to_le_bytes());
                            }
                        }
                    }
                }
            }
        }
    }
    Some(h)
}

/// Stable, readable rule names: the two PR-6 rules keep their historical
/// names, everything else is `lhs-spine~rhs-spine`.
fn rule_name(lhs: &Pattern, rhs: &Pattern) -> String {
    let fuse_affine = Pattern::parse("(add_row (matmul ?a ?b) ?c)").unwrap();
    let affine = Pattern::parse("(affine ?a ?b ?c)").unwrap();
    let fuse_affine_relu = Pattern::parse("(relu (add_row (matmul ?a ?b) ?c))").unwrap();
    let affine_relu = Pattern::parse("(affine_relu ?a ?b ?c)").unwrap();
    if *lhs == fuse_affine && *rhs == affine {
        return "fuse-affine".into();
    }
    if *lhs == fuse_affine_relu && *rhs == affine_relu {
        return "fuse-affine-relu".into();
    }
    format!("{}~{}", spine(lhs), spine(rhs))
}

/// Prefix-order op names of a pattern, joined with `-`.
fn spine(p: &Pattern) -> String {
    fn walk(p: &Pattern, out: &mut Vec<&'static str>) {
        if let Pattern::Op(op, kids) = p {
            out.push(op.name());
            kids.iter().for_each(|k| walk(k, out));
        }
    }
    let mut ops = Vec::new();
    walk(p, &mut ops);
    if ops.is_empty() {
        "id".into()
    } else {
        ops.join("-")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth1_admits_nothing() {
        // Every level-1 term has exactly one op node, so no cluster can
        // contain a strictly-shrinking pair; the run must come back empty
        // without erroring.
        let report = synthesize(&SynthConfig {
            depth: 1,
            seed: 7,
            max_terms_per_level: 400,
            cvec_valuations: 2,
            admit_valuations: 2,
            ..SynthConfig::default()
        });
        assert!(report.enumerated > 0);
        // Size-1 terms only: every cluster member has one op, so no
        // strictly-shrinking rule can exist.
        assert!(report.admitted.is_empty(), "{:?}", report.admitted);
    }

    #[test]
    fn rule_names_are_stable_and_special_cased() {
        let lhs = Pattern::parse("(relu (add_row (matmul ?a ?b) ?c))").unwrap();
        let rhs = Pattern::parse("(affine_relu ?a ?b ?c)").unwrap();
        assert_eq!(rule_name(&lhs, &rhs), "fuse-affine-relu");
        let lhs = Pattern::parse("(relu (relu ?a))").unwrap();
        let rhs = Pattern::parse("(relu ?a)").unwrap();
        assert_eq!(rule_name(&lhs, &rhs), "relu-relu~relu");
    }

    #[test]
    fn synthesis_is_deterministic() {
        let cfg = SynthConfig {
            depth: 2,
            seed: 11,
            max_terms_per_level: 200,
            cvec_valuations: 2,
            admit_valuations: 1,
            ..SynthConfig::default()
        };
        let a = synthesize(&cfg);
        let b = synthesize(&cfg);
        assert_eq!(a.admitted, b.admitted);
        assert_eq!(a.enumerated, b.enumerated);
        assert_eq!(a.candidates, b.candidates);
        assert_eq!(a.derived, b.derived);
    }
}
