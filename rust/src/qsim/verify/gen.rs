//! Enumerative, seeded generator of small tape programs.
//!
//! `gen_case(seed, index)` is a pure function: the same `(seed, index)`
//! pair always yields the same program and the same leaf cvecs, so any
//! fuzzer failure is reproducible from its one-line `FUZZ-REPRO` stamp.
//! Programs are built over the public tape vocabulary (elementwise
//! unary/binary, matmul / matmul_nt, add_row, gather_rows, layernorm,
//! concat_cols, causal_attention) and closed with one of the fused loss
//! heads (softmax_xent, bce_loss, mse over a recorded difference) or a
//! mean cap; the generator is biased
//! toward `matmul + add_row (+ relu)` chains so the rewrite pass always
//! has candidates to validate.

use super::ir::{NodeIr, OpIr, Program};
use crate::qsim::Tensor;
use crate::util::rng::Rng;

/// One generated fuzz case: a lint-clean program plus its leaf tensors.
#[derive(Debug, Clone)]
pub struct Case {
    pub seed: u64,
    pub index: u64,
    pub program: Program,
    pub leaves: Vec<Tensor>,
}

struct Builder {
    nodes: Vec<NodeIr>,
    leaves: Vec<Tensor>,
    rng: Rng,
}

impl Builder {
    fn shape(&self, i: usize) -> (usize, usize) {
        (self.nodes[i].rows, self.nodes[i].cols)
    }

    /// Interior node: the tape marks every non-leaf differentiable.
    fn push(&mut self, op: OpIr, rows: usize, cols: usize) -> usize {
        self.nodes.push(NodeIr { op, rows, cols, requires_grad: true });
        self.nodes.len() - 1
    }

    /// New leaf with seeded normal data (occasionally scaled up to poke
    /// the narrow formats' rounding thresholds).
    fn leaf(&mut self, rows: usize, cols: usize, param: bool) -> usize {
        let scale = if self.rng.below(8) == 0 { 4.0 } else { 1.0 };
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(self.rng.normal() * scale);
        }
        self.leaves.push(Tensor::from_vec(rows, cols, data));
        self.nodes.push(NodeIr { op: OpIr::Leaf, rows, cols, requires_grad: param });
        self.nodes.len() - 1
    }

    /// Leaf that is a parameter ~80% of the time.
    fn maybe_param_leaf(&mut self, rows: usize, cols: usize) -> usize {
        let param = self.rng.below(5) != 0;
        self.leaf(rows, cols, param)
    }

    fn dim(&mut self) -> usize {
        1 + self.rng.below(4)
    }
}

/// Deterministically generate fuzz case `index` of stream `seed`.
pub fn gen_case(seed: u64, index: u64) -> Case {
    let mut b = Builder { nodes: Vec::new(), leaves: Vec::new(), rng: Rng::new(seed, index) };

    // Seed node: always a trainable parameter so gradients flow somewhere.
    let (r0, c0) = (b.dim(), b.dim());
    let first = b.leaf(r0, c0, true);
    let mut avail = vec![first];

    let n_ops = 2 + b.rng.below(5);
    for _ in 0..n_ops {
        let pick = avail[b.rng.below(avail.len())];
        let (r, c) = b.shape(pick);
        let new = match b.rng.below(10) {
            0 => {
                let op = match b.rng.below(3) {
                    0 => OpIr::Relu(pick),
                    1 => OpIr::Sigmoid(pick),
                    _ => OpIr::Tanh(pick),
                };
                b.push(op, r, c)
            }
            1 => {
                let factor = b.rng.uniform_in(-2.0, 2.0);
                b.push(OpIr::Scale(pick, factor), r, c)
            }
            2 => {
                // Binary with a same-shaped partner: reuse an existing node
                // when one fits (exercises shared operands), else a leaf.
                let partner = avail
                    .iter()
                    .copied()
                    .filter(|&o| o != pick && b.shape(o) == (r, c))
                    .last();
                let other = match partner {
                    Some(o) if b.rng.below(2) == 0 => o,
                    _ => b.maybe_param_leaf(r, c),
                };
                let op = match b.rng.below(3) {
                    0 => OpIr::Add(pick, other),
                    1 => OpIr::Sub(pick, other),
                    _ => OpIr::Mul(pick, other),
                };
                b.push(op, r, c)
            }
            3 => {
                let n2 = b.dim();
                let w = b.maybe_param_leaf(c, n2);
                b.push(OpIr::MatMul(pick, w), r, n2)
            }
            4 => {
                let r2 = b.dim();
                let w = b.maybe_param_leaf(r2, c);
                b.push(OpIr::MatMulNT(pick, w), r, r2)
            }
            5 => {
                let bias = b.maybe_param_leaf(1, c);
                b.push(OpIr::AddRow(pick, bias), r, c)
            }
            6 => {
                let n_idx = 1 + b.rng.below(4);
                let idx: Vec<usize> = (0..n_idx).map(|_| b.rng.below(r)).collect();
                b.push(OpIr::GatherRows { x: pick, idx }, n_idx, c)
            }
            7 => b.push(OpIr::LayerNorm { x: pick, eps: 1e-5 }, r, c),
            8 => {
                let c2 = b.dim();
                let other = b.maybe_param_leaf(r, c2);
                b.push(OpIr::ConcatCols(vec![pick, other]), r, c + c2)
            }
            _ => {
                // Biased fusable chain: matmul + add_row (+ relu), the
                // rewrite pass's target pattern.
                let n2 = b.dim();
                let w = b.leaf(c, n2, true);
                let bias = b.leaf(1, n2, true);
                let mm = b.push(OpIr::MatMul(pick, w), r, n2);
                let ar = b.push(OpIr::AddRow(mm, bias), r, n2);
                if b.rng.below(2) == 0 {
                    b.push(OpIr::Relu(ar), r, n2)
                } else {
                    ar
                }
            }
        };
        avail.push(new);
    }

    // Attention gets its own arm (needs three same-shaped operands): bolt
    // it onto the tail occasionally.
    if b.rng.below(4) == 0 {
        let seqs = 1 + b.rng.below(2);
        let tokens = 1 + b.rng.below(3);
        let d = 1 + b.rng.below(3);
        let q = b.leaf(seqs * tokens, d, true);
        let k = b.leaf(seqs * tokens, d, true);
        let v = b.leaf(seqs * tokens, d, true);
        avail.push(b.push(OpIr::CausalAttn { q, k, v, seqs }, seqs * tokens, d));
    }

    // Loss head over the last computed node (keeps the tail live).
    let tail = *avail.last().unwrap();
    let (tr, tc) = b.shape(tail);
    match b.rng.below(4) {
        0 if tc >= 2 => {
            let targets: Vec<usize> = (0..tr).map(|_| b.rng.below(tc)).collect();
            b.push(OpIr::SoftmaxXent { logits: tail, targets }, 1, 1);
        }
        1 => {
            let labels: Vec<f32> =
                (0..tr * tc).map(|_| b.rng.below(2) as f32).collect();
            b.push(OpIr::BceLoss { logits: tail, labels }, 1, 1);
        }
        2 => {
            // Fused MSE head (`Tape::mse_of` over a recorded difference):
            // replayable since the MseLoss standalone fix, so the fuzzer
            // covers the regression-loss path the MLP app trains with.
            let target = b.leaf(tr, tc, false);
            let d = b.push(OpIr::Sub(tail, target), tr, tc);
            b.push(OpIr::MseLoss { diff: d }, 1, 1);
        }
        _ => {
            b.push(OpIr::MeanAll(tail), 1, 1);
        }
    }

    Case { seed, index, program: Program { nodes: b.nodes }, leaves: b.leaves }
}

#[cfg(test)]
mod tests {
    use super::super::lint::lint;
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = gen_case(7, 13);
        let b = gen_case(7, 13);
        assert_eq!(a.program, b.program);
        assert_eq!(a.leaves.len(), b.leaves.len());
        for (x, y) in a.leaves.iter().zip(&b.leaves) {
            assert!(super::super::exec::bits_equal(x, y));
        }
        // A different index must change the stream.
        let c = gen_case(7, 14);
        assert!(a.program != c.program || a.leaves.len() != c.leaves.len());
    }

    #[test]
    fn generated_programs_lint_clean_and_end_scalar() {
        for i in 0..200 {
            let case = gen_case(3, i);
            let root = case.program.nodes.len() - 1;
            let n = &case.program.nodes[root];
            assert_eq!((n.rows, n.cols), (1, 1), "case {i} root is not scalar");
            let errs = lint(&case.program, root).errors();
            assert!(
                errs.is_empty(),
                "case {i} fails lint:\n{}\n{}",
                case.program,
                errs.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
            );
            assert_eq!(
                case.leaves.len(),
                case.program.leaf_nodes().len(),
                "case {i} leaf tensors out of sync with leaf nodes"
            );
        }
    }
}
