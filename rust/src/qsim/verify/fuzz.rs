//! Differential fuzzer over generated tape programs.
//!
//! For every generated case ([`super::gen`]) the fuzzer demands, under
//! every policy-mode compute format (plus the fp16 / e8m5 stress
//! formats):
//!
//! 1. **Backend parity** — `Backend::Fast` at 1 thread is the baseline;
//!    `Fast` at 4 threads, `Reference` at 1 and 4 threads, and `Simd` at
//!    1 and 4 threads must match it bit-for-bit on every node value,
//!    every gradient, and the loss.
//! 2. **Gradient truth** — at fp32, analytic gradients must agree with
//!    dual-step central finite differences (`h = 1e-3` and `5e-4`): a
//!    point only *fails* when the two FD estimates agree with each other
//!    but not with the tape (points straddling a relu kink make the two
//!    estimates disagree and are skipped, not failed).
//! 3. **Ruleset admission** — the whole synthesized ruleset
//!    ([`super::rewrite::admitted_ruleset`]) is applied to fixpoint, and
//!    whenever it changes the program the rewritten form must pass
//!    [`super::rewrite::validate`]'s bit-identity sweep.  Every fuzz run
//!    thus re-proves the checked-in rules on programs the synthesizer
//!    never enumerated.
//!
//! Failures minimize to the shortest failing program prefix and carry a
//! one-line `FUZZ-REPRO seed=S case=I` stamp that replays exactly.

use super::exec;
use super::gen::{self, Case};
use super::ir::OpIr;
use super::rewrite;
use crate::precision::{Format, Mode, BF16, E8M5, FP16, FP32};
use crate::qsim::{Backend, QPolicy};

/// Formats the sweep covers: every `Mode::ALL` compute format over the
/// paper's bf16 default, plus the dynamic-range stress formats.
pub fn sweep_formats() -> Vec<Format> {
    let mut fmts: Vec<Format> = Vec::new();
    for m in Mode::ALL {
        let f = m.compute_fmt(BF16);
        if !fmts.contains(&f) {
            fmts.push(f);
        }
    }
    for f in [FP16, E8M5] {
        if !fmts.contains(&f) {
            fmts.push(f);
        }
    }
    fmts
}

/// One fuzzer failure, minimized.
#[derive(Debug, Clone)]
pub struct FuzzFailure {
    pub seed: u64,
    pub case: u64,
    /// What diverged on the full program.
    pub check: String,
    /// Shortest failing prefix: its listing and its (possibly different)
    /// first failing check.
    pub minimized_program: String,
    pub minimized_check: String,
    pub minimized_nodes: usize,
}

impl FuzzFailure {
    /// The one-line stamp that reproduces this failure.
    pub fn repro_line(&self) -> String {
        format!("FUZZ-REPRO seed={} case={}", self.seed, self.case)
    }

    pub fn render(&self) -> String {
        format!(
            "{}\nfull-program check failed: {}\nminimized to {} nodes \
             (shortest failing prefix):\n{}minimized check: {}",
            self.repro_line(),
            self.check,
            self.minimized_nodes,
            self.minimized_program,
            self.minimized_check
        )
    }
}

/// Aggregate result of a fuzz run.
#[derive(Debug, Clone, Default)]
pub struct FuzzOutcome {
    pub seed: u64,
    pub cases_run: u64,
    /// Individual (format × backend × threads) parity cells compared,
    /// plus FD points and rewrite-admission cells.
    pub checks_run: u64,
    pub rewrites_validated: u64,
    pub failure: Option<FuzzFailure>,
}

impl FuzzOutcome {
    pub fn passed(&self) -> bool {
        self.failure.is_none()
    }
}

/// Fuzz `budget` cases from stream `seed`, stopping at the first failure.
pub fn run(seed: u64, budget: u64) -> FuzzOutcome {
    let mut out = FuzzOutcome { seed, ..FuzzOutcome::default() };
    for i in 0..budget {
        let case = gen::gen_case(seed, i);
        match check_case(&case) {
            Ok(stats) => {
                out.cases_run += 1;
                out.checks_run += stats.checks;
                out.rewrites_validated += stats.rewrites;
            }
            Err(check) => {
                out.failure = Some(minimize(&case, check));
                return out;
            }
        }
    }
    out
}

/// Re-check a single case by its repro coordinates.
pub fn replay_one(seed: u64, case: u64) -> Result<CaseStats, String> {
    check_case(&gen::gen_case(seed, case))
}

#[derive(Debug, Clone, Copy, Default)]
pub struct CaseStats {
    pub checks: u64,
    pub rewrites: u64,
}

/// All checks for one case; `Err` carries the first divergence.
pub fn check_case(case: &Case) -> Result<CaseStats, String> {
    let prog = &case.program;
    let leaves = &case.leaves;
    let mut stats = CaseStats::default();

    for fmt in sweep_formats() {
        let base = exec::run(prog, leaves, QPolicy::with_backend(fmt, Backend::Fast), 1)
            .map_err(|e| format!("replay failed [{} fast t1]: {e}", fmt.name))?;
        for (backend, threads) in [
            (Backend::Fast, 4),
            (Backend::Reference, 1),
            (Backend::Reference, 4),
            (Backend::Simd, 1),
            (Backend::Simd, 4),
        ] {
            let cell = format!("{} {} t{threads}", fmt.name, backend.name());
            let alt = exec::run(prog, leaves, QPolicy::with_backend(fmt, backend), threads)
                .map_err(|e| format!("replay failed [{cell}]: {e}"))?;
            if let Some(d) = exec::diff_replays(&base, &alt) {
                return Err(format!("backend divergence [{cell} vs {} fast t1]: {d}", fmt.name));
            }
            stats.checks += 1;
        }
    }

    stats.checks += fd_check(case)?;

    let rules = rewrite::admitted_ruleset();
    let (rw, applied) = rewrite::rewrite_fixpoint(prog, rules);
    if !applied.is_empty() {
        let cells = rewrite::validate(prog, &rw, leaves)
            .map_err(|e| format!("ruleset rewrite [{}] rejected: {e}", applied.join("; ")))?;
        stats.checks += cells;
        stats.rewrites += applied.len() as u64;
    }

    Ok(stats)
}

/// Dual-step finite-difference gradient check at exact fp32.
fn fd_check(case: &Case) -> Result<u64, String> {
    let prog = &case.program;
    let base = exec::run(prog, &case.leaves, QPolicy::exact(), 1)
        .map_err(|e| format!("fd baseline replay failed: {e}"))?;
    if !base.loss.is_finite() {
        return Ok(0); // degenerate sample; parity checks above still ran
    }
    let mut checks = 0u64;
    for (ord, ni) in prog.leaf_nodes().into_iter().enumerate() {
        if !prog.nodes[ni].requires_grad {
            continue;
        }
        let Some(g) = &base.grads[ni] else { continue }; // dead parameter
        for e in 0..g.data.len() {
            let an = g.data[e] as f64;
            let (Some(fd1), Some(fd2)) = (
                central_diff(case, ord, e, 1e-3)?,
                central_diff(case, ord, e, 5e-4)?,
            ) else {
                continue;
            };
            // Two consistent FD estimates that both disagree with the
            // analytic gradient indict the tape; inconsistent estimates
            // mean the sample straddles a kink — skip, don't fail.
            if (fd1 - fd2).abs() > 0.02 * (1.0 + fd1.abs()) {
                continue;
            }
            if (an - fd1).abs() > 0.1 * (1.0 + fd1.abs()) {
                return Err(format!(
                    "gradient mismatch at param %{ni} element {e}: analytic \
                     {an:.6e} vs finite-difference {fd1:.6e} (h=1e-3, \
                     corroborated at h=5e-4 by {fd2:.6e})"
                ));
            }
            checks += 1;
        }
    }
    Ok(checks)
}

/// Central difference of the loss wrt leaf `ord`, element `e`.  `None`
/// when the perturbed losses go non-finite or the step quantizes away.
fn central_diff(
    case: &Case,
    ord: usize,
    e: usize,
    h: f64,
) -> Result<Option<f64>, String> {
    let x0 = case.leaves[ord].data[e] as f64;
    let hh = h * x0.abs().max(1.0);
    let mut up = case.leaves.clone();
    up[ord].data[e] = (x0 + hh) as f32;
    let mut dn = case.leaves.clone();
    dn[ord].data[e] = (x0 - hh) as f32;
    let eff = up[ord].data[e] as f64 - dn[ord].data[e] as f64;
    if eff == 0.0 {
        return Ok(None);
    }
    let lu = exec::run(&case.program, &up, QPolicy::exact(), 1)
        .map_err(|e| format!("fd replay failed: {e}"))?
        .loss as f64;
    let ld = exec::run(&case.program, &dn, QPolicy::exact(), 1)
        .map_err(|e| format!("fd replay failed: {e}"))?
        .loss as f64;
    if !lu.is_finite() || !ld.is_finite() {
        return Ok(None);
    }
    Ok(Some((lu - ld) / eff))
}

/// Shrink a failing case to its shortest failing program prefix (every
/// prefix of an append-only DAG is itself a closed program; a non-scalar
/// prefix tail is mean-capped by the replayer).
fn minimize(case: &Case, full_check: String) -> FuzzFailure {
    for p in 1..=case.program.nodes.len() {
        let prog = super::ir::Program { nodes: case.program.nodes[..p].to_vec() };
        let n_leaves =
            prog.nodes.iter().filter(|n| matches!(n.op, OpIr::Leaf)).count();
        let sub = Case {
            seed: case.seed,
            index: case.index,
            program: prog,
            leaves: case.leaves[..n_leaves].to_vec(),
        };
        if let Err(check) = check_case(&sub) {
            return FuzzFailure {
                seed: case.seed,
                case: case.index,
                check: full_check,
                minimized_program: sub.program.to_string(),
                minimized_check: check,
                minimized_nodes: sub.program.nodes.len(),
            };
        }
    }
    // The full program failed but no prefix does (should not happen since
    // the last prefix IS the full program) — report it unminimized.
    FuzzFailure {
        seed: case.seed,
        case: case.index,
        check: full_check.clone(),
        minimized_program: case.program.to_string(),
        minimized_check: full_check,
        minimized_nodes: case.program.nodes.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_fp32_and_bf16_and_stress_formats() {
        let fmts = sweep_formats();
        assert!(fmts.contains(&FP32));
        assert!(fmts.contains(&BF16));
        assert!(fmts.contains(&FP16));
        assert!(fmts.contains(&E8M5));
    }

    #[test]
    fn smoke_budget_passes_clean() {
        let out = run(1, 25);
        assert!(
            out.passed(),
            "fuzz failure:\n{}",
            out.failure.as_ref().unwrap().render()
        );
        assert_eq!(out.cases_run, 25);
        assert!(out.checks_run > 0);
    }

    #[test]
    fn replay_one_matches_run() {
        let stats = replay_one(1, 3).expect("case (1,3) must pass");
        assert!(stats.checks > 0);
    }

    #[test]
    fn minimizer_finds_shortest_failing_prefix() {
        // A case that fails in check_case by construction: the supplied
        // leaf tensor is the wrong shape, so every prefix containing the
        // leaf fails to replay — the minimizer must stop at 1 node.
        use super::super::ir::{NodeIr, Program};
        let case = Case {
            seed: 0,
            index: 0,
            program: Program {
                nodes: vec![
                    NodeIr { op: OpIr::Leaf, rows: 2, cols: 2, requires_grad: true },
                    NodeIr { op: OpIr::Relu(0), rows: 2, cols: 2, requires_grad: true },
                    NodeIr { op: OpIr::MeanAll(1), rows: 1, cols: 1, requires_grad: true },
                ],
            },
            leaves: vec![crate::qsim::Tensor::from_vec(
                3,
                3,
                vec![0.5, -0.5, 1.5, -1.5, 0.1, 0.2, 0.3, 0.4, 0.5],
            )],
        };
        let check = check_case(&case).unwrap_err();
        let fail = minimize(&case, check);
        assert_eq!(fail.minimized_nodes, 1, "{}", fail.render());
        assert!(fail.repro_line().contains("seed=0 case=0"));
    }

}
