//! `qsim::verify` — static analysis and differential verification of tape
//! programs.
//!
//! The repo's central claims — `Backend::Fast` ≡ `Backend::Reference`,
//! 1 ≡ N intra-threads, fused kernels ≡ their unfused chains — are exact
//! bitwise contracts, which makes them *mechanically checkable*.  This
//! module is the checker, in three parts:
//!
//! - [`ir`] + [`lint`]: a flat program IR exported from any recorded tape
//!   ([`Tape::export_program`](crate::qsim::Tape::export_program)) and a
//!   structural linter over it (shapes, DAG ordering, grad-flag
//!   conventions, dead nodes, scalar root).  Debug builds run the linter
//!   inside every `Tape::backward`; the `repro lint-tape` subcommand
//!   surfaces it for each app's real training graph.
//! - [`gen`] + [`exec`] + [`fuzz`]: an enumerative, seeded generator of
//!   small programs over the tape vocabulary, a replayer that executes a
//!   program under any `(policy, backend, threads)` cell, and the fuzzer
//!   that demands bitwise parity across all cells plus dual-step
//!   finite-difference agreement at fp32.  `repro fuzz-tape --budget N
//!   --seed S`; every failure minimizes to a prefix and a one-line
//!   `FUZZ-REPRO` stamp.
//! - [`rewrite`]: the generalized pattern-matching rewrite engine, driven
//!   by the synthesized ruleset versioned at
//!   `rust/tests/data/synth_rules.txt`.  A rule is admitted only when
//!   proven bit-identical across the full sweep (formats × backends ×
//!   threads); the fuzzer re-applies the whole ruleset to every program
//!   it generates and re-proves bit-parity.
//! - [`synth`]: Ruler-style rewrite-rule *synthesis* — enumerate small
//!   patterns, cluster by bitwise cvec fingerprints, admit candidates
//!   through the validator.  `repro synth-rules` regenerates and
//!   drift-checks the ruleset.

pub mod exec;
pub mod fuzz;
pub mod gen;
mod ir;
pub mod lint;
pub mod rewrite;
pub mod synth;

pub use ir::{NodeIr, OpIr, Program};
pub use lint::{lint, lint_dither_coords, Diag, DitherCoord, LintReport, Severity};
