//! Run configuration system: TOML files (`configs/*.toml`) + CLI overrides.
//!
//! A `RunConfig` fully determines one training run: the application, the
//! precision mode/format (which select the AOT artifact), step budget,
//! learning-rate schedule, seeds, and eval cadence.  Per-application
//! defaults mirror the paper's Appendix C hyperparameters (scaled).

use anyhow::{bail, Context, Result};
use std::path::Path;

use crate::util::tomlmini::TomlDoc;

/// Learning-rate schedule kinds (the paper's Appendix C set).
#[derive(Debug, Clone, PartialEq)]
pub enum Schedule {
    /// Fixed learning rate (DLRM-Kaggle).
    Constant,
    /// Divide by 10 at given fractions of training (ResNets).
    StepDecay { boundaries: Vec<f64>, factor: f64 },
    /// Linear decay to zero, with a warmup fraction (BERTs, DLRM-Terabyte).
    WarmupLinear { warmup_frac: f64 },
}

impl Schedule {
    /// LR multiplier at `step` of `total`.
    pub fn factor(&self, step: u64, total: u64) -> f64 {
        let t = step as f64 / total.max(1) as f64;
        match self {
            Schedule::Constant => 1.0,
            Schedule::StepDecay { boundaries, factor } => {
                let crossed = boundaries.iter().filter(|&&b| t >= b).count();
                factor.powi(crossed as i32)
            }
            Schedule::WarmupLinear { warmup_frac } => {
                if *warmup_frac > 0.0 && t < *warmup_frac {
                    t / warmup_frac
                } else if *warmup_frac >= 1.0 {
                    1.0
                } else {
                    ((1.0 - t) / (1.0 - warmup_frac)).max(0.0)
                }
            }
        }
    }

    fn parse(kind: &str, warmup: f64, boundaries: &[f64]) -> Result<Schedule> {
        Ok(match kind {
            "constant" => Schedule::Constant,
            "step" => Schedule::StepDecay {
                boundaries: if boundaries.is_empty() {
                    vec![0.45, 0.75]
                } else {
                    boundaries.to_vec()
                },
                factor: 0.1,
            },
            "warmup-linear" | "linear" => Schedule::WarmupLinear { warmup_frac: warmup },
            other => bail!("unknown schedule kind {other:?}"),
        })
    }
}

/// Everything needed to launch one training run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    pub app: String,
    pub mode: String,
    pub fmt: String,
    pub steps: u64,
    pub base_lr: f64,
    pub schedule: Schedule,
    pub seed: u64,
    pub eval_every: u64,
    pub eval_batches: u64,
    pub log_every: u64,
    pub artifacts_dir: String,
    pub out_dir: String,
}

impl RunConfig {
    /// Artifact name in the manifest.
    pub fn artifact_name(&self) -> String {
        if self.fmt == "bf16" {
            format!("{}__{}", self.app, self.mode)
        } else {
            format!("{}__{}-{}", self.app, self.mode, self.fmt)
        }
    }

    /// Per-application defaults (paper Appendix C, scaled to the synthetic
    /// substrate; see DESIGN.md §4-5).
    pub fn defaults_for(app: &str) -> RunConfig {
        let (steps, lr, schedule) = match app {
            "lsq" => (20_000, 0.01, Schedule::Constant),
            // CNN step budgets are scaled for the single-core testbed
            // (~0.14 s and ~0.7 s per step respectively; DESIGN.md §9).
            // lr scaled down vs the paper's 0.1: our CNNs have no batch
            // norm (paper's ResNets do), and bf16 compute at lr 0.1
            // destabilises the un-normalised net.
            "cifar-cnn" => (
                600,
                0.02,
                Schedule::StepDecay { boundaries: vec![0.45, 0.75], factor: 0.1 },
            ),
            "imagenet-cnn" => (
                150,
                0.02,
                Schedule::StepDecay { boundaries: vec![0.33, 0.66], factor: 0.1 },
            ),
            "dlrm-small" => (1_500, 0.1, Schedule::Constant),
            "dlrm-large" => (
                800,
                0.5,
                Schedule::WarmupLinear { warmup_frac: 0.05 },
            ),
            "bert-cls" => (1_200, 2e-3, Schedule::WarmupLinear { warmup_frac: 0.0 }),
            "bert-lm" => (1_200, 1e-3, Schedule::WarmupLinear { warmup_frac: 0.08 }),
            "lstm-seq" => (1_200, 3e-2, Schedule::Constant),
            name if name.starts_with("gpt-") => {
                (300, 1e-3, Schedule::WarmupLinear { warmup_frac: 0.05 })
            }
            _ => (1_000, 0.01, Schedule::Constant),
        };
        RunConfig {
            app: app.to_string(),
            mode: "fp32".to_string(),
            fmt: "bf16".to_string(),
            steps,
            base_lr: lr,
            schedule,
            seed: 0,
            eval_every: (steps / 10).max(1),
            eval_batches: 8,
            log_every: (steps / 200).max(1),
            artifacts_dir: "artifacts".to_string(),
            out_dir: "results".to_string(),
        }
    }

    /// Load from a TOML file, starting from the app defaults.
    pub fn from_toml_file(path: impl AsRef<Path>) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_toml_text(&text)
    }

    pub fn from_toml_text(text: &str) -> Result<RunConfig> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let app = doc
            .get("app")
            .and_then(|v| v.as_str())
            .context("config must set `app`")?
            .to_string();
        let mut cfg = Self::defaults_for(&app);
        cfg.mode = doc.str_or("mode", &cfg.mode).to_string();
        cfg.fmt = doc.str_or("fmt", &cfg.fmt).to_string();
        cfg.steps = doc.i64_or("train.steps", cfg.steps as i64) as u64;
        cfg.base_lr = doc.f64_or("train.lr", cfg.base_lr);
        cfg.seed = doc.i64_or("train.seed", cfg.seed as i64) as u64;
        cfg.eval_every = doc.i64_or("eval.every", cfg.eval_every as i64) as u64;
        cfg.eval_batches = doc.i64_or("eval.batches", cfg.eval_batches as i64) as u64;
        cfg.log_every = doc.i64_or("train.log_every", cfg.log_every as i64) as u64;
        cfg.artifacts_dir = doc.str_or("paths.artifacts", &cfg.artifacts_dir).to_string();
        cfg.out_dir = doc.str_or("paths.out", &cfg.out_dir).to_string();
        if let Some(kind) = doc.get("schedule.kind").and_then(|v| v.as_str()) {
            let warmup = doc.f64_or("schedule.warmup_frac", 0.0);
            let boundaries: Vec<f64> = doc
                .get("schedule.boundaries")
                .and_then(|v| match v {
                    crate::util::tomlmini::TomlValue::Array(a) => {
                        Some(a.iter().filter_map(|x| x.as_f64()).collect())
                    }
                    _ => None,
                })
                .unwrap_or_default();
            cfg.schedule = Schedule::parse(kind, warmup, &boundaries)?;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_shape() {
        let c = Schedule::Constant;
        assert_eq!(c.factor(500, 1000), 1.0);
        let s = Schedule::StepDecay { boundaries: vec![0.5, 0.75], factor: 0.1 };
        assert_eq!(s.factor(0, 1000), 1.0);
        assert!((s.factor(500, 1000) - 0.1).abs() < 1e-12);
        assert!((s.factor(900, 1000) - 0.01).abs() < 1e-12);
        let w = Schedule::WarmupLinear { warmup_frac: 0.1 };
        assert!(w.factor(50, 1000) < 1.0); // warming up
        assert!((w.factor(100, 1000) - 1.0).abs() < 1e-9);
        assert!(w.factor(999, 1000) < 0.01);
    }

    #[test]
    fn schedule_is_monotone_after_warmup() {
        let w = Schedule::WarmupLinear { warmup_frac: 0.08 };
        let mut prev = f64::INFINITY;
        for step in (80..1000).step_by(10) {
            let f = w.factor(step, 1000);
            assert!(f <= prev + 1e-12);
            prev = f;
        }
    }

    #[test]
    fn toml_overrides_defaults() {
        let cfg = RunConfig::from_toml_text(
            r#"
app = "dlrm-small"
mode = "sr16"
fmt = "e8m5"
[train]
steps = 50
lr = 0.2
seed = 3
[schedule]
kind = "warmup-linear"
warmup_frac = 0.1
"#,
        )
        .unwrap();
        assert_eq!(cfg.artifact_name(), "dlrm-small__sr16-e8m5");
        assert_eq!(cfg.steps, 50);
        assert_eq!(cfg.base_lr, 0.2);
        assert_eq!(cfg.seed, 3);
        assert_eq!(cfg.schedule, Schedule::WarmupLinear { warmup_frac: 0.1 });
    }

    #[test]
    fn bf16_artifact_name_has_no_suffix() {
        let cfg = RunConfig::defaults_for("lsq");
        assert_eq!(cfg.artifact_name(), "lsq__fp32");
    }

    #[test]
    fn missing_app_is_error() {
        assert!(RunConfig::from_toml_text("mode = \"fp32\"").is_err());
    }
}
