//! Run configuration system: TOML files (`configs/*.toml`) + CLI overrides.
//!
//! A `RunConfig` fully determines one training run: the application, the
//! typed [`Policy`] (which selects the AOT artifact), step budget,
//! learning-rate schedule, seeds, and eval cadence.  Per-application
//! defaults mirror the paper's Appendix C hyperparameters (scaled).
//!
//! Prefer building configs through the [`RunSpec`] builder — it starts from
//! the application defaults and rescales the eval/log cadence when the step
//! budget changes, instead of callers poking raw fields.

use anyhow::{bail, Context, Result};
use std::path::Path;

use crate::precision::{Format, Policy};
use crate::qsim::Backend;
use crate::util::tomlmini::TomlDoc;

/// Learning-rate schedule kinds (the paper's Appendix C set).
#[derive(Debug, Clone, PartialEq)]
pub enum Schedule {
    /// Fixed learning rate (DLRM-Kaggle).
    Constant,
    /// Divide by 10 at given fractions of training (ResNets).
    StepDecay { boundaries: Vec<f64>, factor: f64 },
    /// Linear decay to zero, with a warmup fraction (BERTs, DLRM-Terabyte).
    WarmupLinear { warmup_frac: f64 },
}

impl Schedule {
    /// LR multiplier at `step` of `total`.
    pub fn factor(&self, step: u64, total: u64) -> f64 {
        let t = step as f64 / total.max(1) as f64;
        match self {
            Schedule::Constant => 1.0,
            Schedule::StepDecay { boundaries, factor } => {
                let crossed = boundaries.iter().filter(|&&b| t >= b).count();
                factor.powi(crossed as i32)
            }
            Schedule::WarmupLinear { warmup_frac } => {
                if *warmup_frac > 0.0 && t < *warmup_frac {
                    t / warmup_frac
                } else if *warmup_frac >= 1.0 {
                    1.0
                } else {
                    ((1.0 - t) / (1.0 - warmup_frac)).max(0.0)
                }
            }
        }
    }

    fn parse(kind: &str, warmup: f64, boundaries: &[f64]) -> Result<Schedule> {
        Ok(match kind {
            "constant" => Schedule::Constant,
            "step" => Schedule::StepDecay {
                boundaries: if boundaries.is_empty() {
                    vec![0.45, 0.75]
                } else {
                    boundaries.to_vec()
                },
                factor: 0.1,
            },
            "warmup-linear" | "linear" => Schedule::WarmupLinear { warmup_frac: warmup },
            other => bail!("unknown schedule kind {other:?}"),
        })
    }
}

/// Everything needed to launch one training run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    pub app: String,
    pub policy: Policy,
    pub steps: u64,
    pub base_lr: f64,
    pub schedule: Schedule,
    pub seed: u64,
    pub eval_every: u64,
    pub eval_batches: u64,
    pub log_every: u64,
    pub artifacts_dir: String,
    pub out_dir: String,
    /// Worker threads *within* one train step (`--intra-threads`; TOML key
    /// `train.intra_threads`).  `1` = sequential (default), `0` = available
    /// parallelism.  Honored by the qsim-native kernels (the fig5/fig9
    /// experiments, `qsim-parity`, the native benches); the PJRT session
    /// path records it in its `RunSummary` but executes its lowered
    /// programs as compiled.  SR dither is counter-keyed, so results are
    /// bit-identical at every setting.  Distinct from sweep-level
    /// `--threads`, which fans *runs* out across workers — a multi-worker
    /// sweep clamps auto (`0`) cells back to `1` to avoid oversubscription.
    pub intra_threads: usize,
    /// Kernel backend tier for the qsim-native paths (`--backend`; TOML key
    /// `train.backend`: `fast` (default), `reference`, `simd`).  All tiers
    /// are bit-identical, so this only trades wall-clock; the PJRT session
    /// path ignores it (its kernels are compiled artifacts).
    pub backend: Backend,
    /// Data-parallel worker shards for the qsim-native trainer (`--shards`;
    /// TOML key `train.shards`).  `0` (default) runs the legacy in-process
    /// loop; `N >= 1` routes through [`crate::qsim::ShardedTrainer`], which
    /// is bit-identical to the single-process loop at every power-of-two
    /// shard count (the step's microbatch gradients reduce over a fixed
    /// tree regardless of which shard computed them).
    pub shards: usize,
    /// Microbatches accumulated per optimizer step on the sharded path
    /// (`--grad-accum`; TOML key `train.grad_accum`).  Must be a power of
    /// two and a multiple of `shards`.  `1` reproduces the unsharded
    /// single-batch step bit-for-bit.
    pub grad_accum: usize,
    /// Deterministic fault-injection spec for the sharded path (`--chaos`;
    /// TOML key `train.chaos`), parsed by
    /// [`crate::qsim::ChaosConfig::parse`] — e.g. `"light"`, `"heavy"`, or
    /// pinned events like `"crash@2.1,stall@4.3:80"`.  `None` disables
    /// injection.  Any schedule yields bit-identical training results;
    /// chaos only perturbs timing and the recovery counters.
    pub chaos: Option<String>,
    /// Inference-serving knobs for `repro serve` (`[serve]` TOML table:
    /// `serve.addr`, `serve.batch_window_us`, `serve.max_batch`,
    /// `serve.backend`), validated at parse time like every other key.
    /// The window/batch knobs only shape latency — batching never changes
    /// a scored bit, so they need no fingerprint or parity coverage.
    pub serve: crate::qsim::ServeConfig,
}

impl RunConfig {
    /// Artifact name in the manifest.
    pub fn artifact_name(&self) -> String {
        self.policy.artifact_name(&self.app)
    }

    /// Per-application defaults (paper Appendix C, scaled to the synthetic
    /// substrate; see DESIGN.md §4-5).
    pub fn defaults_for(app: &str) -> RunConfig {
        let (steps, lr, schedule) = match app {
            "lsq" => (20_000, 0.01, Schedule::Constant),
            // CNN step budgets are scaled for the single-core testbed
            // (~0.14 s and ~0.7 s per step respectively; DESIGN.md §9).
            // lr scaled down vs the paper's 0.1: our CNNs have no batch
            // norm (paper's ResNets do), and bf16 compute at lr 0.1
            // destabilises the un-normalised net.
            "cifar-cnn" => (
                600,
                0.02,
                Schedule::StepDecay { boundaries: vec![0.45, 0.75], factor: 0.1 },
            ),
            "imagenet-cnn" => (
                150,
                0.02,
                Schedule::StepDecay { boundaries: vec![0.33, 0.66], factor: 0.1 },
            ),
            "dlrm-small" => (1_500, 0.1, Schedule::Constant),
            "dlrm-large" => (
                800,
                0.5,
                Schedule::WarmupLinear { warmup_frac: 0.05 },
            ),
            "bert-cls" => (1_200, 2e-3, Schedule::WarmupLinear { warmup_frac: 0.0 }),
            "bert-lm" => (1_200, 1e-3, Schedule::WarmupLinear { warmup_frac: 0.08 }),
            "lstm-seq" => (1_200, 3e-2, Schedule::Constant),
            // native qsim apps (`repro train --native`, `repro exp mlp`):
            // budgets/lr match the native experiment harness
            "dlrm" => (1_000, 0.05, Schedule::Constant),
            "mlp" => (600, 0.3, Schedule::WarmupLinear { warmup_frac: 0.05 }),
            // bare "gpt" is the experiment id the CLI also accepts for the
            // native app — same budget as its canonical "gpt-nano" name
            "gpt" | "gpt-nano" => (300, 0.2, Schedule::WarmupLinear { warmup_frac: 0.05 }),
            name if name.starts_with("gpt-") => {
                (300, 1e-3, Schedule::WarmupLinear { warmup_frac: 0.05 })
            }
            _ => (1_000, 0.01, Schedule::Constant),
        };
        RunConfig {
            app: app.to_string(),
            policy: Policy::default(),
            steps,
            base_lr: lr,
            schedule,
            seed: 0,
            eval_every: (steps / 10).max(1),
            eval_batches: 8,
            log_every: (steps / 200).max(1),
            artifacts_dir: "artifacts".to_string(),
            out_dir: "results".to_string(),
            intra_threads: 1,
            backend: Backend::default(),
            shards: 0,
            grad_accum: 1,
            chaos: None,
            serve: crate::qsim::ServeConfig::default(),
        }
    }

    /// Load from a TOML file, starting from the app defaults.
    pub fn from_toml_file(path: impl AsRef<Path>) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_toml_text(&text)
    }

    pub fn from_toml_text(text: &str) -> Result<RunConfig> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let app = doc
            .get("app")
            .and_then(|v| v.as_str())
            .context("config must set `app`")?
            .to_string();
        let mut cfg = Self::defaults_for(&app);
        // precision: either a combined `policy = "sr16-e8m5"` key, or the
        // legacy `mode` / `fmt` pair — all validated by the typed parser.
        if let Some(p) = doc.get("policy").and_then(|v| v.as_str()) {
            cfg.policy = Policy::parse(p).with_context(|| format!("config key `policy` = {p:?}"))?;
        }
        if let Some(m) = doc.get("mode").and_then(|v| v.as_str()) {
            let mode = m.parse().with_context(|| format!("config key `mode` = {m:?}"))?;
            cfg.policy = Policy::new(mode, cfg.policy.fmt);
        }
        if let Some(f) = doc.get("fmt").and_then(|v| v.as_str()) {
            let fmt = Format::by_name(f)
                .with_context(|| format!("config key `fmt` = {f:?} is not a known format"))?;
            cfg.policy = Policy::new(cfg.policy.mode, fmt);
        }
        cfg.steps = doc.i64_or("train.steps", cfg.steps as i64) as u64;
        cfg.base_lr = doc.f64_or("train.lr", cfg.base_lr);
        cfg.seed = doc.i64_or("train.seed", cfg.seed as i64) as u64;
        cfg.eval_every = doc.i64_or("eval.every", cfg.eval_every as i64) as u64;
        cfg.eval_batches = doc.i64_or("eval.batches", cfg.eval_batches as i64) as u64;
        cfg.log_every = doc.i64_or("train.log_every", cfg.log_every as i64) as u64;
        cfg.artifacts_dir = doc.str_or("paths.artifacts", &cfg.artifacts_dir).to_string();
        cfg.out_dir = doc.str_or("paths.out", &cfg.out_dir).to_string();
        // .max(0): a negative TOML value must not wrap through `as usize`
        // into an astronomical thread count — treat it as auto (0)
        cfg.intra_threads =
            doc.i64_or("train.intra_threads", cfg.intra_threads as i64).max(0) as usize;
        if let Some(b) = doc.get("train.backend").and_then(|v| v.as_str()) {
            cfg.backend = Backend::by_name(b).with_context(|| {
                format!("config key `train.backend` = {b:?} (expected fast, reference or simd)")
            })?;
        }
        // .max(0): negative values must not wrap through `as usize`
        cfg.shards = doc.i64_or("train.shards", cfg.shards as i64).max(0) as usize;
        cfg.grad_accum = doc.i64_or("train.grad_accum", cfg.grad_accum as i64).max(1) as usize;
        if let Some(c) = doc.get("train.chaos").and_then(|v| v.as_str()) {
            // validate eagerly so a typo'd schedule fails at config parse
            // time, not steps into the run
            crate::qsim::ChaosConfig::parse(c)
                .with_context(|| format!("config key `train.chaos` = {c:?}"))?;
            cfg.chaos = Some(c.to_string());
        }
        cfg.serve.addr = doc.str_or("serve.addr", &cfg.serve.addr).to_string();
        if !cfg.serve.addr.contains(':') {
            bail!("config key `serve.addr` = {:?} must be host:port", cfg.serve.addr);
        }
        // .max(0): negative values must not wrap through `as u64`
        cfg.serve.batch_window_us =
            doc.i64_or("serve.batch_window_us", cfg.serve.batch_window_us as i64).max(0) as u64;
        let max_batch = doc.i64_or("serve.max_batch", cfg.serve.max_batch as i64);
        if max_batch < 1 {
            bail!("config key `serve.max_batch` = {max_batch} must be >= 1");
        }
        cfg.serve.max_batch = max_batch as usize;
        if let Some(b) = doc.get("serve.backend").and_then(|v| v.as_str()) {
            cfg.serve.backend = Backend::by_name(b).with_context(|| {
                format!("config key `serve.backend` = {b:?} (expected fast, reference or simd)")
            })?;
        }
        if let Some(kind) = doc.get("schedule.kind").and_then(|v| v.as_str()) {
            let warmup = doc.f64_or("schedule.warmup_frac", 0.0);
            let boundaries: Vec<f64> = doc
                .get("schedule.boundaries")
                .and_then(|v| match v {
                    crate::util::tomlmini::TomlValue::Array(a) => {
                        Some(a.iter().filter_map(|x| x.as_f64()).collect())
                    }
                    _ => None,
                })
                .unwrap_or_default();
            cfg.schedule = Schedule::parse(kind, warmup, &boundaries)?;
        }
        Ok(cfg)
    }
}

/// Builder for [`RunConfig`] — the single way run parameters are assembled
/// across the CLI, the library [`Runner`](crate::Runner) facade, the
/// [`Sweep`](crate::coordinator::Sweep) grid, and the examples.
///
/// ```ignore
/// let cfg = RunSpec::new("dlrm-small")
///     .policy(Policy::bf16(Mode::Sr16))
///     .steps(600)
///     .seed(3)
///     .build();
/// ```
///
/// `build` starts from the per-application defaults (or an explicit base
/// config via [`RunSpec::from_config`]) and applies only the fields that
/// were set.  Overriding `steps` rescales `eval_every`/`log_every` with the
/// default cadence unless those were set explicitly too.
#[derive(Debug, Clone)]
pub struct RunSpec {
    base: RunConfig,
    /// Whether the base cadence is derived app defaults (safe to rescale
    /// when `steps` changes) rather than explicit user configuration.
    rescale_cadence: bool,
    policy: Option<Policy>,
    steps: Option<u64>,
    seed: Option<u64>,
    lr: Option<f64>,
    schedule: Option<Schedule>,
    eval_every: Option<u64>,
    eval_batches: Option<u64>,
    log_every: Option<u64>,
    artifacts_dir: Option<String>,
    out_dir: Option<String>,
    intra_threads: Option<usize>,
    backend: Option<Backend>,
    shards: Option<usize>,
    grad_accum: Option<usize>,
    chaos: Option<Option<String>>,
}

impl RunSpec {
    /// Start from the per-application defaults.
    pub fn new(app: &str) -> RunSpec {
        let mut spec = Self::from_config(RunConfig::defaults_for(app));
        spec.rescale_cadence = true;
        spec
    }

    /// Start from an explicit base config (e.g. one loaded from TOML).
    /// Its eval/log cadence is preserved even when `steps` is overridden.
    pub fn from_config(base: RunConfig) -> RunSpec {
        RunSpec {
            base,
            rescale_cadence: false,
            policy: None,
            steps: None,
            seed: None,
            lr: None,
            schedule: None,
            eval_every: None,
            eval_batches: None,
            log_every: None,
            artifacts_dir: None,
            out_dir: None,
            intra_threads: None,
            backend: None,
            shards: None,
            grad_accum: None,
            chaos: None,
        }
    }

    pub fn app(&self) -> &str {
        &self.base.app
    }

    pub fn policy(mut self, p: Policy) -> Self {
        self.policy = Some(p);
        self
    }

    pub fn steps(mut self, n: u64) -> Self {
        self.steps = Some(n);
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = Some(s);
        self
    }

    pub fn lr(mut self, lr: f64) -> Self {
        self.lr = Some(lr);
        self
    }

    pub fn schedule(mut self, s: Schedule) -> Self {
        self.schedule = Some(s);
        self
    }

    pub fn eval_every(mut self, n: u64) -> Self {
        self.eval_every = Some(n);
        self
    }

    pub fn eval_batches(mut self, n: u64) -> Self {
        self.eval_batches = Some(n);
        self
    }

    pub fn log_every(mut self, n: u64) -> Self {
        self.log_every = Some(n);
        self
    }

    pub fn artifacts_dir(mut self, dir: &str) -> Self {
        self.artifacts_dir = Some(dir.to_string());
        self
    }

    pub fn out_dir(mut self, dir: &str) -> Self {
        self.out_dir = Some(dir.to_string());
        self
    }

    /// Intra-step worker threads (1 = sequential, 0 = auto).  Results are
    /// bit-identical at every setting; this only trades wall-clock.
    pub fn intra_threads(mut self, n: usize) -> Self {
        self.intra_threads = Some(n);
        self
    }

    /// Kernel backend tier for the qsim-native paths.  All tiers are
    /// bit-identical; this only trades wall-clock.
    pub fn backend(mut self, b: Backend) -> Self {
        self.backend = Some(b);
        self
    }

    /// Data-parallel worker shards (0 = legacy in-process loop).  Results
    /// are bit-identical at every power-of-two shard count.
    pub fn shards(mut self, n: usize) -> Self {
        self.shards = Some(n);
        self
    }

    /// Microbatches accumulated per optimizer step on the sharded path.
    pub fn grad_accum(mut self, n: usize) -> Self {
        self.grad_accum = Some(n);
        self
    }

    /// Deterministic chaos schedule for the sharded path (`None` disables).
    pub fn chaos(mut self, spec: Option<String>) -> Self {
        self.chaos = Some(spec);
        self
    }

    /// Materialize the final [`RunConfig`].
    pub fn build(&self) -> RunConfig {
        let mut cfg = self.base.clone();
        if let Some(p) = self.policy {
            cfg.policy = p;
        }
        if let Some(s) = self.steps {
            if s != cfg.steps {
                cfg.steps = s;
                // keep the *default* cadence relative to the new budget;
                // an explicit base (TOML) cadence is never overridden
                if self.rescale_cadence {
                    if self.eval_every.is_none() {
                        cfg.eval_every = (s / 10).max(1);
                    }
                    if self.log_every.is_none() {
                        cfg.log_every = (s / 200).max(1);
                    }
                }
            }
        }
        if let Some(s) = self.seed {
            cfg.seed = s;
        }
        if let Some(lr) = self.lr {
            cfg.base_lr = lr;
        }
        if let Some(sched) = &self.schedule {
            cfg.schedule = sched.clone();
        }
        if let Some(n) = self.eval_every {
            cfg.eval_every = n;
        }
        if let Some(n) = self.eval_batches {
            cfg.eval_batches = n;
        }
        if let Some(n) = self.log_every {
            cfg.log_every = n;
        }
        if let Some(d) = &self.artifacts_dir {
            cfg.artifacts_dir = d.clone();
        }
        if let Some(d) = &self.out_dir {
            cfg.out_dir = d.clone();
        }
        if let Some(n) = self.intra_threads {
            cfg.intra_threads = n;
        }
        if let Some(b) = self.backend {
            cfg.backend = b;
        }
        if let Some(n) = self.shards {
            cfg.shards = n;
        }
        if let Some(n) = self.grad_accum {
            cfg.grad_accum = n;
        }
        if let Some(c) = &self.chaos {
            cfg.chaos = c.clone();
        }
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::{Mode, E8M5};

    #[test]
    fn schedules_shape() {
        let c = Schedule::Constant;
        assert_eq!(c.factor(500, 1000), 1.0);
        let s = Schedule::StepDecay { boundaries: vec![0.5, 0.75], factor: 0.1 };
        assert_eq!(s.factor(0, 1000), 1.0);
        assert!((s.factor(500, 1000) - 0.1).abs() < 1e-12);
        assert!((s.factor(900, 1000) - 0.01).abs() < 1e-12);
        let w = Schedule::WarmupLinear { warmup_frac: 0.1 };
        assert!(w.factor(50, 1000) < 1.0); // warming up
        assert!((w.factor(100, 1000) - 1.0).abs() < 1e-9);
        assert!(w.factor(999, 1000) < 0.01);
    }

    #[test]
    fn schedule_is_monotone_after_warmup() {
        let w = Schedule::WarmupLinear { warmup_frac: 0.08 };
        let mut prev = f64::INFINITY;
        for step in (80..1000).step_by(10) {
            let f = w.factor(step, 1000);
            assert!(f <= prev + 1e-12);
            prev = f;
        }
    }

    #[test]
    fn toml_overrides_defaults() {
        let cfg = RunConfig::from_toml_text(
            r#"
app = "dlrm-small"
mode = "sr16"
fmt = "e8m5"
[train]
steps = 50
lr = 0.2
seed = 3
[schedule]
kind = "warmup-linear"
warmup_frac = 0.1
"#,
        )
        .unwrap();
        assert_eq!(cfg.policy, Policy::new(Mode::Sr16, E8M5));
        assert_eq!(cfg.artifact_name(), "dlrm-small__sr16-e8m5");
        assert_eq!(cfg.steps, 50);
        assert_eq!(cfg.base_lr, 0.2);
        assert_eq!(cfg.seed, 3);
        assert_eq!(cfg.schedule, Schedule::WarmupLinear { warmup_frac: 0.1 });
    }

    #[test]
    fn toml_combined_policy_key() {
        let cfg = RunConfig::from_toml_text("app = \"lsq\"\npolicy = \"kahan16-e8m5\"").unwrap();
        assert_eq!(cfg.policy, Policy::new(Mode::Kahan16, E8M5));
    }

    #[test]
    fn toml_rejects_unknown_mode_or_fmt() {
        assert!(RunConfig::from_toml_text("app = \"lsq\"\nmode = \"bogus\"").is_err());
        assert!(RunConfig::from_toml_text("app = \"lsq\"\nfmt = \"e9m9\"").is_err());
        assert!(RunConfig::from_toml_text("app = \"lsq\"\npolicy = \"sr16-\"").is_err());
    }

    #[test]
    fn bf16_artifact_name_has_no_suffix() {
        let cfg = RunConfig::defaults_for("lsq");
        assert_eq!(cfg.artifact_name(), "lsq__fp32");
    }

    #[test]
    fn missing_app_is_error() {
        assert!(RunConfig::from_toml_text("mode = \"fp32\"").is_err());
    }

    #[test]
    fn runspec_applies_overrides_on_defaults() {
        let cfg = RunSpec::new("dlrm-small")
            .policy(Policy::bf16(Mode::Sr16))
            .steps(600)
            .seed(7)
            .build();
        assert_eq!(cfg.app, "dlrm-small");
        assert_eq!(cfg.artifact_name(), "dlrm-small__sr16");
        assert_eq!(cfg.steps, 600);
        assert_eq!(cfg.seed, 7);
        // cadence rescaled to the new budget
        assert_eq!(cfg.eval_every, 60);
        assert_eq!(cfg.log_every, 3);
    }

    #[test]
    fn native_app_defaults_are_consistent() {
        // both accepted spellings of the native gpt app share one budget
        let gpt = RunConfig::defaults_for("gpt");
        let nano = RunConfig::defaults_for("gpt-nano");
        assert_eq!((gpt.steps, gpt.base_lr), (nano.steps, nano.base_lr));
        let mlp = RunConfig::defaults_for("mlp");
        assert_eq!(mlp.steps, 600);
        assert_eq!(mlp.base_lr, 0.3);
    }

    #[test]
    fn intra_threads_defaults_parses_and_overrides() {
        let cfg = RunConfig::defaults_for("dlrm-small");
        assert_eq!(cfg.intra_threads, 1, "sequential by default");
        let cfg = RunConfig::from_toml_text(
            "app = \"dlrm-small\"\n[train]\nintra_threads = 4\n",
        )
        .unwrap();
        assert_eq!(cfg.intra_threads, 4);
        let spec = RunSpec::new("dlrm-small").intra_threads(2);
        assert_eq!(spec.build().intra_threads, 2);
    }

    #[test]
    fn backend_defaults_parses_and_overrides() {
        let cfg = RunConfig::defaults_for("dlrm-small");
        assert_eq!(cfg.backend, Backend::Fast, "fast by default");
        let cfg =
            RunConfig::from_toml_text("app = \"dlrm\"\n[train]\nbackend = \"simd\"\n").unwrap();
        assert_eq!(cfg.backend, Backend::Simd);
        let err = RunConfig::from_toml_text("app = \"dlrm\"\n[train]\nbackend = \"avx99\"\n");
        assert!(err.is_err(), "unknown backend names must fail at parse time");
        let spec = RunSpec::new("mlp").backend(Backend::Reference);
        assert_eq!(spec.build().backend, Backend::Reference);
    }

    #[test]
    fn shard_keys_default_parse_and_override() {
        let cfg = RunConfig::defaults_for("dlrm");
        assert_eq!((cfg.shards, cfg.grad_accum, cfg.chaos.as_deref()), (0, 1, None));
        let cfg = RunConfig::from_toml_text(
            "app = \"dlrm\"\n[train]\nshards = 2\ngrad_accum = 4\nchaos = \"light\"\n",
        )
        .unwrap();
        assert_eq!((cfg.shards, cfg.grad_accum, cfg.chaos.as_deref()), (2, 4, Some("light")));
        // a malformed chaos schedule fails at config parse time
        let err =
            RunConfig::from_toml_text("app = \"dlrm\"\n[train]\nchaos = \"explode@x\"\n");
        assert!(err.is_err(), "bad chaos spec must be rejected");
        let spec = RunSpec::new("mlp").shards(4).grad_accum(8).chaos(Some("heavy".into()));
        let cfg = spec.build();
        assert_eq!((cfg.shards, cfg.grad_accum, cfg.chaos.as_deref()), (4, 8, Some("heavy")));
    }

    #[test]
    fn serve_keys_default_parse_and_validate() {
        use crate::qsim::ServeConfig;
        let cfg = RunConfig::defaults_for("dlrm");
        assert_eq!(cfg.serve, ServeConfig::default());
        let cfg = RunConfig::from_toml_text(
            "app = \"dlrm\"\n[serve]\naddr = \"0.0.0.0:9100\"\nbatch_window_us = 500\n\
             max_batch = 64\nbackend = \"simd\"\n",
        )
        .unwrap();
        assert_eq!(cfg.serve.addr, "0.0.0.0:9100");
        assert_eq!(cfg.serve.batch_window_us, 500);
        assert_eq!(cfg.serve.max_batch, 64);
        assert_eq!(cfg.serve.backend, Backend::Simd);
        // every serve key is validated at parse time, not at bind time
        for bad in [
            "app = \"dlrm\"\n[serve]\naddr = \"noport\"\n",
            "app = \"dlrm\"\n[serve]\nmax_batch = 0\n",
            "app = \"dlrm\"\n[serve]\nbackend = \"cuda\"\n",
        ] {
            assert!(RunConfig::from_toml_text(bad).is_err(), "must reject {bad:?}");
        }
    }

    #[test]
    fn runspec_explicit_cadence_wins_over_rescale() {
        let cfg = RunSpec::new("dlrm-small").steps(600).eval_every(600).build();
        assert_eq!(cfg.eval_every, 600);
        assert_eq!(cfg.log_every, 3); // still rescaled
    }

    #[test]
    fn runspec_same_steps_keeps_base_cadence() {
        let base = RunConfig::defaults_for("dlrm-small");
        let cfg = RunSpec::from_config(base.clone()).steps(base.steps).build();
        assert_eq!(cfg, base);
    }

    #[test]
    fn runspec_from_config_preserves_explicit_cadence_on_steps_override() {
        // a TOML-style base with explicit eval/log cadence must survive a
        // --steps override untouched
        let mut base = RunConfig::defaults_for("lsq");
        base.eval_every = 50;
        base.log_every = 7;
        let cfg = RunSpec::from_config(base).steps(1000).build();
        assert_eq!(cfg.steps, 1000);
        assert_eq!(cfg.eval_every, 50);
        assert_eq!(cfg.log_every, 7);
    }
}
