//! Typed view of `artifacts/manifest.json` produced by `python -m compile.aot`.
//!
//! The manifest is the only contract between the build-time python layer and
//! the runtime rust layer: it records, per artifact, the exact ordered list
//! of executable inputs/outputs with their roles, shapes and dtypes, plus the
//! application/precision metadata the coordinator uses to label runs.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::precision::Policy;
use crate::util::json::Json;

/// Role of one executable input/output slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Param,
    OptState,
    X,
    Y,
    Seed,
    Lr,
    Loss,
    Metric,
    CancelFrac,
    Preds,
}

impl Role {
    fn parse(s: &str) -> Result<Role> {
        Ok(match s {
            "param" => Role::Param,
            "opt_state" => Role::OptState,
            "x" => Role::X,
            "y" => Role::Y,
            "seed" => Role::Seed,
            "lr" => Role::Lr,
            "loss" => Role::Loss,
            "metric" => Role::Metric,
            "cancel_frac" => Role::CancelFrac,
            "preds" => Role::Preds,
            other => bail!("unknown slot role {other:?}"),
        })
    }
}

/// Element type of one slot (all emulated formats travel as F32).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            other => bail!("unknown dtype {other:?}"),
        })
    }
}

/// One ordered input/output slot of an executable.
#[derive(Debug, Clone)]
pub struct Slot {
    pub role: Role,
    pub key: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl Slot {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<Slot> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("slot missing shape")?
            .iter()
            .map(|d| d.as_usize().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(Slot {
            role: Role::parse(j.get_str("role").context("slot missing role")?)?,
            key: j.get_str("key").unwrap_or("").to_string(),
            shape,
            dtype: DType::parse(j.get_str("dtype").context("slot missing dtype")?)?,
        })
    }
}

/// File names of the three executables of one artifact.
#[derive(Debug, Clone)]
pub struct Files {
    pub train: String,
    pub eval: String,
    pub init: String,
}

/// One (application × precision-mode) artifact entry.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub app: String,
    pub mode: String,
    pub fmt: String,
    pub family: String,
    pub optimizer: String,
    pub metric_name: String,
    pub paper_ref: String,
    pub batch: usize,
    pub hparams: HashMap<String, i64>,
    pub train_inputs: Vec<Slot>,
    pub train_outputs: Vec<Slot>,
    pub eval_inputs: Vec<Slot>,
    pub eval_outputs: Vec<Slot>,
    pub num_params: usize,
    pub num_opt_state: usize,
    pub param_elements: usize,
    pub files: Files,
}

fn slots(j: &Json, key: &str) -> Result<Vec<Slot>> {
    j.get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("artifact missing {key}"))?
        .iter()
        .map(Slot::from_json)
        .collect()
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.get_str(key).with_context(|| format!("artifact missing {key}"))?.to_string())
}

impl Artifact {
    fn from_json(j: &Json) -> Result<Artifact> {
        let files = j.get("files").context("artifact missing files")?;
        let mut hparams = HashMap::new();
        if let Some(hp) = j.get("hparams").and_then(Json::as_obj) {
            for (k, v) in hp {
                if let Some(i) = v.as_i64() {
                    hparams.insert(k.clone(), i);
                }
            }
        }
        Ok(Artifact {
            name: req_str(j, "name")?,
            app: req_str(j, "app")?,
            mode: req_str(j, "mode")?,
            fmt: req_str(j, "fmt")?,
            family: req_str(j, "family")?,
            optimizer: req_str(j, "optimizer")?,
            metric_name: req_str(j, "metric_name")?,
            paper_ref: j.get_str("paper_ref").unwrap_or("").to_string(),
            batch: j.get_usize("batch").context("artifact missing batch")?,
            hparams,
            train_inputs: slots(j, "train_inputs")?,
            train_outputs: slots(j, "train_outputs")?,
            eval_inputs: slots(j, "eval_inputs")?,
            eval_outputs: slots(j, "eval_outputs")?,
            num_params: j.get_usize("num_params").context("missing num_params")?,
            num_opt_state: j.get_usize("num_opt_state").context("missing num_opt_state")?,
            param_elements: j.get_usize("param_elements").unwrap_or(0),
            files: Files {
                train: req_str(files, "train")?,
                eval: req_str(files, "eval")?,
                init: req_str(files, "init")?,
            },
        })
    }

    /// Shape/dtype of the `x` batch input.
    pub fn x_slot(&self) -> &Slot {
        self.train_inputs
            .iter()
            .find(|s| s.role == Role::X)
            .expect("manifest artifact lacks x slot")
    }

    /// Shape/dtype of the `y` batch input.
    pub fn y_slot(&self) -> &Slot {
        self.train_inputs
            .iter()
            .find(|s| s.role == Role::Y)
            .expect("manifest artifact lacks y slot")
    }

    /// Integer hparam (0 if missing).
    pub fn hparam(&self, key: &str) -> i64 {
        self.hparams.get(key).copied().unwrap_or(0)
    }

    /// Typed precision policy from the manifest's mode/fmt metadata.
    pub fn policy(&self) -> Result<Policy> {
        Policy::from_parts(&self.mode, &self.fmt)
            .with_context(|| format!("artifact {:?} metadata", self.name))
    }
}

/// The whole manifest plus its directory (for resolving file names).
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<Artifact>,
    index: HashMap<String, usize>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::from_json_text(&text, dir)
    }

    /// Parse manifest JSON (exposed for tests).
    pub fn from_json_text(text: &str, dir: PathBuf) -> Result<Self> {
        let doc = Json::parse(text).context("parsing manifest.json")?;
        let artifacts = doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing artifacts")?
            .iter()
            .map(Artifact::from_json)
            .collect::<Result<Vec<_>>>()?;
        let index = artifacts
            .iter()
            .enumerate()
            .map(|(i, a)| (a.name.clone(), i))
            .collect();
        Ok(Self { dir, artifacts, index })
    }

    /// Look up an artifact by name (`app__mode` or `app__mode-fmt`).
    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.index.get(name).map(|&i| &self.artifacts[i]).with_context(|| {
            let names: Vec<_> = self.artifacts.iter().map(|a| a.name.as_str()).collect();
            format!("artifact {name:?} not in manifest; have: {names:?}")
        })
    }

    /// All artifacts of one application.
    pub fn for_app(&self, app: &str) -> Vec<&Artifact> {
        self.artifacts.iter().filter(|a| a.app == app).collect()
    }

    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [{
        "name": "lsq__sr16", "app": "lsq", "mode": "sr16", "fmt": "bf16",
        "family": "mlp", "optimizer": "sgd", "metric_name": "loss",
        "paper_ref": "", "batch": 1, "hparams": {"in_dim": 10},
        "train_inputs": [
          {"role":"param","key":"l0.b","shape":[1],"dtype":"f32"},
          {"role":"param","key":"l0.w","shape":[10,1],"dtype":"f32"},
          {"role":"x","key":"","shape":[1,10],"dtype":"f32"},
          {"role":"y","key":"","shape":[1],"dtype":"f32"},
          {"role":"seed","key":"","shape":[],"dtype":"i32"},
          {"role":"lr","key":"","shape":[],"dtype":"f32"}],
        "train_outputs": [
          {"role":"param","key":"l0.b","shape":[1],"dtype":"f32"},
          {"role":"param","key":"l0.w","shape":[10,1],"dtype":"f32"},
          {"role":"loss","key":"","shape":[],"dtype":"f32"},
          {"role":"metric","key":"","shape":[],"dtype":"f32"},
          {"role":"cancel_frac","key":"","shape":[],"dtype":"f32"}],
        "eval_inputs": [], "eval_outputs": [],
        "num_params": 2, "num_opt_state": 0, "param_elements": 11,
        "files": {"train":"a","eval":"b","init":"c"}
      }]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json_text(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let a = m.get("lsq__sr16").unwrap();
        assert_eq!(a.train_inputs.len(), 6);
        assert_eq!(a.x_slot().shape, vec![1, 10]);
        assert_eq!(a.y_slot().dtype, DType::F32);
        assert_eq!(a.train_inputs[4].role, Role::Seed);
        assert_eq!(a.hparam("in_dim"), 10);
        assert_eq!(a.train_inputs[1].elements(), 10);
        assert_eq!(m.for_app("lsq").len(), 1);
        assert!(m.get("nope").is_err());
        let p = a.policy().unwrap();
        assert_eq!(p, Policy::parse("sr16").unwrap());
        assert_eq!(p.artifact_name(&a.app), a.name);
    }

    #[test]
    fn scalar_slot_has_one_element() {
        let s = Slot { role: Role::Lr, key: String::new(), shape: vec![], dtype: DType::F32 };
        assert_eq!(s.elements(), 1);
    }
}
