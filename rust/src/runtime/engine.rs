//! PJRT execution engine: loads AOT-compiled HLO text and runs it.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin).  One [`Engine`] owns the
//! PJRT client and a cache of compiled executables keyed by file path, so a
//! coordinator sweeping many precision modes compiles each artifact once.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::xla;

/// Process-wide PJRT client + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU PJRT engine.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text file (cached).
    pub fn compile_file(
        &self,
        path: impl AsRef<Path>,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let path = path.as_ref().to_path_buf();
        if let Some(exe) = self.cache.lock().unwrap().get(&path) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {path:?}"))?,
        );
        self.cache.lock().unwrap().insert(path, exe.clone());
        Ok(exe)
    }

    /// Execute with literal inputs; returns the flattened output literals.
    ///
    /// AOT lowering uses `return_tuple=True`, so the executable produces one
    /// tuple; this unpacks it into the manifest's output order.
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = args.iter().collect();
        self.run_refs(exe, &refs)
    }

    /// Execute with borrowed literal inputs (no state copies on the hot path).
    pub fn run_refs(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let out = exe.execute::<&xla::Literal>(args).context("pjrt execute")?;
        let mut first = out
            .into_iter()
            .next()
            .context("no output device")?
            .into_iter()
            .next()
            .context("no output buffer")?
            .to_literal_sync()
            .context("output to literal")?;
        // Output is a single tuple literal; decompose into elements.
        if first.shape().map(|s| s.is_tuple()).unwrap_or(false) {
            Ok(first.decompose_tuple()?)
        } else {
            Ok(vec![first])
        }
    }
}
