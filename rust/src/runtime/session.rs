//! Training session: owns the model/optimizer state for one artifact and
//! drives its train/eval/init executables.
//!
//! State (parameters, optimizer moments, Kahan buffers) stays in the order
//! fixed by the manifest; the session shuttles it through the train step and
//! never interprets it — the numeric format lives inside the lowered graph.

use anyhow::{bail, Context, Result};

use crate::precision::Policy;

use super::engine::Engine;
use super::manifest::{Artifact, DType, Manifest, Role, Slot};
use super::xla;

/// One host-side batch matching the artifact's x/y slots.
#[derive(Debug, Clone, PartialEq)]
pub enum BatchData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl BatchData {
    pub fn len(&self) -> usize {
        match self {
            BatchData::F32(v) => v.len(),
            BatchData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn to_literal(&self, slot: &Slot) -> Result<xla::Literal> {
        let dims: Vec<i64> = slot.shape.iter().map(|&d| d as i64).collect();
        let lit = match (self, slot.dtype) {
            (BatchData::F32(v), DType::F32) => xla::Literal::vec1(v),
            (BatchData::I32(v), DType::I32) => xla::Literal::vec1(v),
            _ => bail!(
                "batch dtype mismatch for slot role {:?} (want {:?})",
                slot.role,
                slot.dtype
            ),
        };
        if lit.element_count() != slot.elements() {
            bail!(
                "batch size mismatch: got {} elements, slot {:?} wants {}",
                lit.element_count(),
                slot.role,
                slot.elements()
            );
        }
        Ok(if dims.is_empty() { lit } else { lit.reshape(&dims)? })
    }
}

/// Scalar results of one training step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepStats {
    pub loss: f32,
    pub metric: f32,
    /// Fraction of non-zero weight updates cancelled by rounding (Fig 9).
    pub cancel_frac: f32,
}

/// Results of one eval batch.
#[derive(Debug, Clone)]
pub struct EvalStats {
    pub loss: f32,
    pub metric: f32,
    pub preds: Vec<f32>,
}

/// Live training state bound to one artifact's executables.
pub struct TrainSession {
    pub artifact: Artifact,
    train_exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
    eval_exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
    init_exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
    /// params + opt_state literals in manifest order.
    state: Vec<xla::Literal>,
    pub steps_done: u64,
}

impl TrainSession {
    /// Typed entry point: open the session for `app` under `policy`.
    pub fn open(engine: &Engine, manifest: &Manifest, app: &str, policy: Policy) -> Result<Self> {
        Self::new(engine, manifest, &policy.artifact_name(app))
    }

    /// Compile (or fetch from cache) the artifact's executables.
    pub fn new(engine: &Engine, manifest: &Manifest, name: &str) -> Result<Self> {
        let artifact = manifest.get(name)?.clone();
        let train_exe = engine.compile_file(manifest.path_of(&artifact.files.train))?;
        let eval_exe = engine.compile_file(manifest.path_of(&artifact.files.eval))?;
        let init_exe = engine.compile_file(manifest.path_of(&artifact.files.init))?;
        Ok(Self { artifact, train_exe, eval_exe, init_exe, state: Vec::new(), steps_done: 0 })
    }

    /// Number of state tensors (params + optimizer state).
    pub fn state_len(&self) -> usize {
        self.artifact.num_params + self.artifact.num_opt_state
    }

    /// Initialize model + optimizer state from a seed (runs the init graph).
    pub fn init(&mut self, engine: &Engine, seed: i32) -> Result<()> {
        let out = engine.run(&self.init_exe, &[xla::Literal::scalar(seed)])?;
        if out.len() != self.state_len() {
            bail!(
                "init produced {} tensors, manifest expects {}",
                out.len(),
                self.state_len()
            );
        }
        self.state = out;
        self.steps_done = 0;
        Ok(())
    }

    /// Run one training step; state is replaced by the step outputs.
    pub fn step(
        &mut self,
        engine: &Engine,
        x: &BatchData,
        y: &BatchData,
        seed: i32,
        lr: f32,
    ) -> Result<StepStats> {
        if self.state.is_empty() {
            bail!("session not initialized (call init first)");
        }
        let a = &self.artifact;
        let n = self.state_len();
        // Bind by manifest slot roles: state tensors in order, then the
        // batch/scalar inputs wherever the (possibly pruned) signature puts
        // them.  Non-stochastic modes have no seed slot (see train_step.py).
        let mut xl = None;
        let mut yl = None;
        let mut seedl = None;
        let mut lrl = None;
        for slot in &a.train_inputs {
            match slot.role {
                Role::X => xl = Some(x.to_literal(slot)?),
                Role::Y => yl = Some(y.to_literal(slot)?),
                Role::Seed => seedl = Some(xla::Literal::scalar(seed)),
                Role::Lr => lrl = Some(xla::Literal::scalar(lr)),
                _ => {}
            }
        }
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(a.train_inputs.len());
        let mut state_it = self.state.iter();
        for slot in &a.train_inputs {
            args.push(match slot.role {
                Role::Param | Role::OptState => {
                    state_it.next().context("state tensor count mismatch")?
                }
                Role::X => xl.as_ref().unwrap(),
                Role::Y => yl.as_ref().unwrap(),
                Role::Seed => seedl.as_ref().unwrap(),
                Role::Lr => lrl.as_ref().unwrap(),
                other => bail!("unexpected train input role {other:?}"),
            });
        }
        let mut out = engine.run_refs(&self.train_exe, &args)?;
        let _ = n;
        let expected = a.train_outputs.len();
        if out.len() != expected {
            bail!("train step produced {} outputs, expected {}", out.len(), expected);
        }
        let cancel_frac = scalar_f32(&out.pop().unwrap())?;
        let metric = scalar_f32(&out.pop().unwrap())?;
        let loss = scalar_f32(&out.pop().unwrap())?;
        self.state = out;
        self.steps_done += 1;
        Ok(StepStats { loss, metric, cancel_frac })
    }

    /// Evaluate one batch with the current parameters.
    pub fn eval(&self, engine: &Engine, x: &BatchData, y: &BatchData) -> Result<EvalStats> {
        if self.state.is_empty() {
            bail!("session not initialized (call init first)");
        }
        let a = &self.artifact;
        let np = a.num_params;
        let xl = x.to_literal(&a.eval_inputs[np])?;
        let yl = y.to_literal(&a.eval_inputs[np + 1])?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(np + 2);
        args.extend(self.state.iter().take(np));
        args.extend([&xl, &yl]);
        let out = engine.run_refs(&self.eval_exe, &args)?;
        if out.len() != 3 {
            bail!("eval produced {} outputs, expected 3", out.len());
        }
        Ok(EvalStats {
            loss: scalar_f32(&out[0])?,
            metric: scalar_f32(&out[1])?,
            preds: out[2].to_vec::<f32>()?,
        })
    }

    /// Copy one state tensor to host (by manifest slot index).
    pub fn state_host(&self, idx: usize) -> Result<Vec<f32>> {
        self.state
            .get(idx)
            .context("state index out of range")?
            .to_vec::<f32>()
            .map_err(Into::into)
    }

    /// Overwrite one state tensor from host values (e.g. checkpoint restore).
    pub fn set_state(&mut self, idx: usize, values: &[f32]) -> Result<()> {
        let slot = &self.artifact.train_inputs[idx];
        if values.len() != slot.elements() {
            bail!("set_state size mismatch");
        }
        let dims: Vec<i64> = slot.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(values);
        self.state[idx] = if dims.is_empty() { lit } else { lit.reshape(&dims)? };
        Ok(())
    }
}

fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.to_vec::<f32>()?[0])
}
