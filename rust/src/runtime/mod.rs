//! Runtime layer: PJRT engine + artifact manifest + training sessions.
//!
//! Loads the HLO-text artifacts produced by `python -m compile.aot` (the only
//! place python runs) and executes them from the rust request path.
//!
//! The PJRT dependency (the `xla` crate + XLA C library) is gated behind the
//! `pjrt` cargo feature.  Without it, the crate still builds — the native
//! `qsim` experiments, the precision substrate and all pure components work —
//! and `Engine::cpu()` returns a clear runtime error instead.

mod engine;
mod manifest;
mod session;

#[cfg(feature = "pjrt")]
pub(crate) use ::xla;
#[cfg(not(feature = "pjrt"))]
pub(crate) mod xla;

pub use engine::Engine;
pub use manifest::{Artifact, DType, Files, Manifest, Role, Slot};
pub use session::{BatchData, EvalStats, StepStats, TrainSession};
