//! Runtime layer: PJRT engine + artifact manifest + training sessions.
//!
//! Loads the HLO-text artifacts produced by `python -m compile.aot` (the only
//! place python runs) and executes them from the rust request path.

mod engine;
mod manifest;
mod session;

pub use engine::Engine;
pub use manifest::{Artifact, DType, Files, Manifest, Role, Slot};
pub use session::{BatchData, EvalStats, StepStats, TrainSession};
