//! Minimal stand-in for the `xla` crate, compiled when the `pjrt` cargo
//! feature is off (the default in dependency-free builds).
//!
//! Mirrors exactly the API surface `engine.rs`/`session.rs` use, so the
//! whole crate type-checks without the XLA C library; every entry point that
//! would touch PJRT returns a runtime error instead.  Enable `--features
//! pjrt` to link the real bindings.

use std::fmt;

/// Error for any stubbed PJRT call.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT runtime not compiled in: rebuild with `--features pjrt` \
         (requires the `xla` crate and the XLA C library)"
            .to_string(),
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "pjrt-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_vals: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T>(_val: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn element_count(&self) -> usize {
        0
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn shape(&self) -> Result<Shape> {
        unavailable()
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

pub struct Shape;

impl Shape {
    pub fn is_tuple(&self) -> bool {
        false
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
