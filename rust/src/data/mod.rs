//! Synthetic data pipeline (L3).
//!
//! The paper's datasets (CIFAR10, ImageNet, Criteo, MNLI, Wiki103,
//! LibriSpeech) are substituted with deterministic synthetic generators that
//! exercise the same code paths and learning dynamics (DESIGN.md §4): every
//! generator has a *ground-truth model* so training has real signal, and is
//! seeded per (seed, split) so train/valid are disjoint and reproducible.
//!
//! Generators emit batches in exactly the layout the manifest's x/y slots
//! require — the coordinator never reshapes data.

use anyhow::{bail, Result};

use crate::runtime::{Artifact, BatchData, DType};
use crate::util::rng::{Rng, ZipfTable};

/// A batch source bound to one artifact's x/y layout.
pub trait Dataset: Send {
    /// Next (x, y) batch.
    fn next_batch(&mut self) -> (BatchData, BatchData);
    /// Human-readable name.
    fn name(&self) -> &str;
    /// Fast-forward past `n` batches without materializing them (checkpoint
    /// resume).  Implementations must consume *exactly* the RNG stream of
    /// `n` `next_batch` calls; the default falls back to generating and
    /// discarding the batches.
    fn skip(&mut self, n: u64) {
        for _ in 0..n {
            let _ = self.next_batch();
        }
    }
}

/// Build the right generator for an artifact (by model family).
pub fn for_artifact(a: &Artifact, seed: u64, split: Split) -> Result<Box<dyn Dataset>> {
    let stream = match split {
        Split::Train => 0x7E,
        Split::Valid => 0xE7,
    };
    let b = a.batch;
    Ok(match a.family.as_str() {
        "mlp" => {
            let dim = a.hparam("in_dim").max(1) as usize;
            Box::new(Regression::new(dim, b, seed, stream))
        }
        "cnn" => {
            let classes = a.hparam("num_classes").max(2) as usize;
            let image = a.hparam("image").max(8) as usize;
            Box::new(Images::new(image, classes, b, seed, stream))
        }
        "dlrm" => {
            let dense = a.hparam("dense_dim").max(1) as usize;
            let tables = a.hparam("num_tables").max(1) as usize;
            let tsize = a.hparam("table_size").max(2) as usize;
            Box::new(Ctr::new(dense, tables, tsize, b, seed, stream))
        }
        "transformer" => {
            let vocab = a.hparam("vocab").max(4) as usize;
            let seq = a.hparam("seq").max(2) as usize;
            let y = a.y_slot();
            if y.dtype == DType::I32 && y.shape.len() == 2 {
                Box::new(TokenLm::new(vocab, seq, b, seed, stream))
            } else {
                let classes = a.hparam("num_classes").max(2) as usize;
                Box::new(TokenCls::new(vocab, seq, classes, b, seed, stream))
            }
        }
        "lstm" => {
            let in_dim = a.hparam("in_dim").max(1) as usize;
            let seq = a.hparam("seq").max(2) as usize;
            let classes = a.hparam("num_classes").max(2) as usize;
            Box::new(SeqFrames::new(in_dim, seq, classes, b, seed, stream))
        }
        other => bail!("no dataset generator for model family {other:?}"),
    })
}

/// Train/validation split selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Valid,
}

// ---------------------------------------------------------------------------
// Least-squares regression (the theory workload).
// ---------------------------------------------------------------------------

/// y = x·w* + noise, w* ~ U[0, 100) (paper §3.1 setup).
pub struct Regression {
    dim: usize,
    batch: usize,
    w_star: Vec<f32>,
    rng: Rng,
    noise: f32,
}

impl Regression {
    pub fn new(dim: usize, batch: usize, seed: u64, stream: u64) -> Self {
        // ground truth depends only on the seed, not the split stream
        let mut truth_rng = Rng::new(seed, 0x17);
        let w_star = (0..dim).map(|_| truth_rng.uniform_in(0.0, 100.0)).collect();
        Self { dim, batch, w_star, rng: Rng::new(seed, stream), noise: 0.5 }
    }

    pub fn w_star(&self) -> &[f32] {
        &self.w_star
    }
}

impl Dataset for Regression {
    fn next_batch(&mut self) -> (BatchData, BatchData) {
        let mut x = Vec::with_capacity(self.batch * self.dim);
        let mut y = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let mut dot = 0f32;
            for &w in &self.w_star {
                let v = self.rng.normal();
                x.push(v);
                dot += v * w;
            }
            y.push(dot + self.rng.normal() * self.noise);
        }
        (BatchData::F32(x), BatchData::F32(y))
    }

    fn name(&self) -> &str {
        "synthetic-regression"
    }

    fn skip(&mut self, n: u64) {
        // mirror next_batch: dim feature normals + 1 noise normal per row
        for _ in 0..n * self.batch as u64 {
            for _ in 0..self.dim {
                self.rng.normal();
            }
            self.rng.normal();
        }
    }
}

// ---------------------------------------------------------------------------
// Class-structured images (CIFAR/ImageNet stand-in).
// ---------------------------------------------------------------------------

/// Per-class smooth template + pixel noise, NCHW 3-channel.
pub struct Images {
    image: usize,
    classes: usize,
    batch: usize,
    templates: Vec<f32>, // classes × 3 × image × image
    rng: Rng,
}

impl Images {
    pub fn new(image: usize, classes: usize, batch: usize, seed: u64, stream: u64) -> Self {
        let mut truth_rng = Rng::new(seed, 0x1A);
        let per = 3 * image * image;
        let mut templates = vec![0f32; classes * per];
        for c in 0..classes {
            // smooth low-frequency template: sum of a few random sinusoids
            let fx = truth_rng.uniform_in(0.5, 3.0);
            let fy = truth_rng.uniform_in(0.5, 3.0);
            let phase = truth_rng.uniform_in(0.0, 6.28);
            for ch in 0..3 {
                let amp = truth_rng.uniform_in(0.5, 1.5);
                for i in 0..image {
                    for j in 0..image {
                        let v = amp
                            * ((fx * i as f32 / image as f32 * 6.28 + phase).sin()
                                + (fy * j as f32 / image as f32 * 6.28).cos());
                        templates[c * per + ch * image * image + i * image + j] = v * 0.5;
                    }
                }
            }
        }
        Self { image, classes, batch, templates, rng: Rng::new(seed, stream) }
    }
}

impl Dataset for Images {
    fn next_batch(&mut self) -> (BatchData, BatchData) {
        let per = 3 * self.image * self.image;
        let mut x = Vec::with_capacity(self.batch * per);
        let mut y = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let c = self.rng.below(self.classes);
            y.push(c as i32);
            let t = &self.templates[c * per..(c + 1) * per];
            for &tv in t {
                x.push(tv + self.rng.normal() * 0.3);
            }
        }
        (BatchData::F32(x), BatchData::I32(y))
    }

    fn name(&self) -> &str {
        "synthetic-images"
    }

    fn skip(&mut self, n: u64) {
        // mirror next_batch: 1 class draw + one pixel-noise normal per value
        let per = 3 * self.image * self.image;
        for _ in 0..n * self.batch as u64 {
            self.rng.below(self.classes);
            for _ in 0..per {
                self.rng.normal();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Click-through logs (Criteo stand-in).
// ---------------------------------------------------------------------------

/// Dense gaussian features + Zipf categorical ids, logistic ground truth.
/// x layout = [dense | indices-as-f32] (see python models/dlrm.py).
pub struct Ctr {
    dense: usize,
    tables: usize,
    table_size: usize,
    batch: usize,
    zipf: ZipfTable,
    truth_dense: Vec<f32>,
    truth_cat: Vec<f32>,
    rng: Rng,
}

impl Ctr {
    pub fn new(
        dense: usize,
        tables: usize,
        table_size: usize,
        batch: usize,
        seed: u64,
        stream: u64,
    ) -> Self {
        let mut truth_rng = Rng::new(seed, 0x1C);
        Self {
            dense,
            tables,
            table_size,
            batch,
            zipf: ZipfTable::new(table_size, 1.1),
            truth_dense: (0..dense).map(|_| truth_rng.normal() * 0.7).collect(),
            truth_cat: (0..tables * table_size)
                .map(|_| truth_rng.normal() * 0.5)
                .collect(),
            rng: Rng::new(seed, stream),
        }
    }
}

impl Dataset for Ctr {
    fn next_batch(&mut self) -> (BatchData, BatchData) {
        let cols = self.dense + self.tables;
        let mut x = Vec::with_capacity(self.batch * cols);
        let mut y = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let mut logit = -0.3f32; // slight negative bias: CTR-like rates
            for d in 0..self.dense {
                let v = self.rng.normal();
                x.push(v);
                logit += v * self.truth_dense[d];
            }
            for t in 0..self.tables {
                let idx = self.rng.zipf(&self.zipf);
                x.push(idx as f32);
                logit += self.truth_cat[t * self.table_size + idx];
            }
            let p = 1.0 / (1.0 + (-logit).exp());
            y.push(if self.rng.uniform() < p { 1.0 } else { 0.0 });
        }
        (BatchData::F32(x), BatchData::F32(y))
    }

    fn name(&self) -> &str {
        "synthetic-ctr"
    }

    fn skip(&mut self, n: u64) {
        // mirror next_batch: dense normals + per-table zipf + 1 label uniform
        for _ in 0..n * self.batch as u64 {
            for _ in 0..self.dense {
                self.rng.normal();
            }
            for _ in 0..self.tables {
                self.rng.zipf(&self.zipf);
            }
            self.rng.uniform();
        }
    }
}

// ---------------------------------------------------------------------------
// Token sequences (MNLI / Wiki103 / GPT stand-ins).
// ---------------------------------------------------------------------------

/// Classification: the label is a (noisy) function of bag-of-token hashes —
/// learnable by an encoder, not by a constant predictor.
pub struct TokenCls {
    seq: usize,
    classes: usize,
    batch: usize,
    zipf: ZipfTable,
    token_class_affinity: Vec<u8>, // vocab → class hint
    rng: Rng,
}

impl TokenCls {
    pub fn new(
        vocab: usize,
        seq: usize,
        classes: usize,
        batch: usize,
        seed: u64,
        stream: u64,
    ) -> Self {
        let mut truth_rng = Rng::new(seed, 0x1D);
        Self {
            seq,
            classes,
            batch,
            zipf: ZipfTable::new(vocab, 1.05),
            token_class_affinity: (0..vocab)
                .map(|_| truth_rng.below(classes) as u8)
                .collect(),
            rng: Rng::new(seed, stream),
        }
    }
}

impl Dataset for TokenCls {
    fn next_batch(&mut self) -> (BatchData, BatchData) {
        let mut x = Vec::with_capacity(self.batch * self.seq);
        let mut y = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            // draw a class, then bias token draws toward that class's tokens
            let c = self.rng.below(self.classes);
            let mut votes = vec![0usize; self.classes];
            for _ in 0..self.seq {
                let mut tok = self.rng.zipf(&self.zipf);
                // resample once toward the class to create signal
                if self.token_class_affinity[tok] as usize != c && self.rng.uniform() < 0.6 {
                    tok = self.rng.zipf(&self.zipf);
                }
                votes[self.token_class_affinity[tok] as usize] += 1;
                x.push(tok as i32);
            }
            // label = majority affinity (deterministic given tokens)
            let label = votes
                .iter()
                .enumerate()
                .max_by_key(|(_, &v)| v)
                .map(|(i, _)| i)
                .unwrap_or(0);
            y.push(label as i32);
        }
        (BatchData::I32(x), BatchData::I32(y))
    }

    fn name(&self) -> &str {
        "synthetic-entailment"
    }

    fn skip(&mut self, n: u64) {
        // mirror next_batch exactly, including the conditional resample
        for _ in 0..n * self.batch as u64 {
            let c = self.rng.below(self.classes);
            for _ in 0..self.seq {
                let tok = self.rng.zipf(&self.zipf);
                if self.token_class_affinity[tok] as usize != c && self.rng.uniform() < 0.6 {
                    self.rng.zipf(&self.zipf);
                }
            }
        }
    }
}

/// Causal LM: first-order Markov chain over a Zipf vocabulary; targets are
/// inputs shifted by one (y[t] = x[t+1], last target wraps to x[0]).
pub struct TokenLm {
    seq: usize,
    batch: usize,
    zipf: ZipfTable,
    /// sparse transition preferences: each token has k preferred successors
    succ: Vec<u32>,
    k: usize,
    rng: Rng,
}

impl TokenLm {
    pub fn new(vocab: usize, seq: usize, batch: usize, seed: u64, stream: u64) -> Self {
        let mut truth_rng = Rng::new(seed, 0x1E);
        let k = 4;
        let succ = (0..vocab * k)
            .map(|_| truth_rng.below(vocab) as u32)
            .collect();
        Self {
            seq,
            batch,
            zipf: ZipfTable::new(vocab, 1.1),
            succ,
            k,
            rng: Rng::new(seed, stream),
        }
    }

    fn next_token(&mut self, prev: usize) -> usize {
        if self.rng.uniform() < 0.75 {
            // follow the Markov structure (learnable signal)
            self.succ[prev * self.k + self.rng.below(self.k)] as usize
        } else {
            self.rng.zipf(&self.zipf)
        }
    }
}

impl Dataset for TokenLm {
    fn next_batch(&mut self) -> (BatchData, BatchData) {
        let mut x = Vec::with_capacity(self.batch * self.seq);
        let mut y = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let mut tok = self.rng.zipf(&self.zipf);
            let mut row = Vec::with_capacity(self.seq + 1);
            row.push(tok);
            for _ in 0..self.seq {
                tok = self.next_token(tok);
                row.push(tok);
            }
            for t in 0..self.seq {
                x.push(row[t] as i32);
                y.push(row[t + 1] as i32);
            }
        }
        (BatchData::I32(x), BatchData::I32(y))
    }

    fn name(&self) -> &str {
        "synthetic-markov-lm"
    }

    fn skip(&mut self, n: u64) {
        // mirror next_batch: initial zipf + seq chained next_token draws
        for _ in 0..n * self.batch as u64 {
            let mut tok = self.rng.zipf(&self.zipf);
            for _ in 0..self.seq {
                tok = self.next_token(tok);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Feature-frame sequences (LibriSpeech stand-in).
// ---------------------------------------------------------------------------

/// Random smooth feature trajectories; per-frame labels from a fixed linear
/// frame classifier (so a (Bi)LSTM can fit them).
pub struct SeqFrames {
    in_dim: usize,
    seq: usize,
    classes: usize,
    batch: usize,
    truth_w: Vec<f32>, // in_dim × classes
    rng: Rng,
}

impl SeqFrames {
    pub fn new(
        in_dim: usize,
        seq: usize,
        classes: usize,
        batch: usize,
        seed: u64,
        stream: u64,
    ) -> Self {
        let mut truth_rng = Rng::new(seed, 0x1F);
        Self {
            in_dim,
            seq,
            classes,
            batch,
            truth_w: (0..in_dim * classes).map(|_| truth_rng.normal()).collect(),
            rng: Rng::new(seed, stream),
        }
    }
}

impl Dataset for SeqFrames {
    fn next_batch(&mut self) -> (BatchData, BatchData) {
        let mut x = Vec::with_capacity(self.batch * self.seq * self.in_dim);
        let mut y = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            // smooth trajectory: AR(1) per feature dim
            let mut frame: Vec<f32> = (0..self.in_dim).map(|_| self.rng.normal()).collect();
            for _ in 0..self.seq {
                for f in frame.iter_mut() {
                    *f = 0.8 * *f + 0.2 * self.rng.normal();
                }
                // frame label from the ground-truth linear classifier
                let mut best = (f32::NEG_INFINITY, 0usize);
                for c in 0..self.classes {
                    let mut s = 0f32;
                    for (d, &fv) in frame.iter().enumerate() {
                        s += fv * self.truth_w[d * self.classes + c];
                    }
                    if s > best.0 {
                        best = (s, c);
                    }
                }
                x.extend_from_slice(&frame);
                y.push(best.1 as i32);
            }
        }
        (BatchData::F32(x), BatchData::I32(y))
    }

    fn name(&self) -> &str {
        "synthetic-frames"
    }

    fn skip(&mut self, n: u64) {
        // mirror next_batch: in_dim init normals + in_dim normals per frame
        // (the label argmax draws nothing)
        for _ in 0..n * self.batch as u64 {
            for _ in 0..self.in_dim {
                self.rng.normal();
            }
            for _ in 0..self.seq {
                for _ in 0..self.in_dim {
                    self.rng.normal();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regression_reproducible_and_split_disjoint() {
        let mut a = Regression::new(10, 4, 1, 0x7E);
        let mut b = Regression::new(10, 4, 1, 0x7E);
        let mut v = Regression::new(10, 4, 1, 0xE7);
        assert_eq!(a.next_batch(), b.next_batch());
        assert_ne!(a.next_batch(), v.next_batch());
        assert_eq!(a.w_star(), v.w_star()); // same ground truth
    }

    #[test]
    fn images_labels_in_range() {
        let mut g = Images::new(16, 10, 8, 2, 0);
        let (x, y) = g.next_batch();
        assert_eq!(x.len(), 8 * 3 * 16 * 16);
        if let BatchData::I32(ys) = y {
            assert!(ys.iter().all(|&c| (0..10).contains(&c)));
        } else {
            panic!("labels must be i32");
        }
    }

    #[test]
    fn ctr_indices_are_valid_and_integral() {
        let mut g = Ctr::new(13, 8, 100, 32, 3, 0);
        let (x, y) = g.next_batch();
        if let BatchData::F32(xs) = &x {
            assert_eq!(xs.len(), 32 * (13 + 8));
            for r in 0..32 {
                for t in 0..8 {
                    let v = xs[r * 21 + 13 + t];
                    assert_eq!(v.fract(), 0.0);
                    assert!((0.0..100.0).contains(&v));
                }
            }
        } else {
            panic!()
        }
        if let BatchData::F32(ys) = y {
            assert!(ys.iter().all(|&v| v == 0.0 || v == 1.0));
        } else {
            panic!()
        }
    }

    #[test]
    fn ctr_labels_correlate_with_truth() {
        // the generator must be learnable: positive rate varies with logit
        let mut g = Ctr::new(4, 2, 50, 256, 5, 0);
        let mut pos = 0usize;
        let mut n = 0usize;
        for _ in 0..20 {
            let (_, y) = g.next_batch();
            if let BatchData::F32(ys) = y {
                pos += ys.iter().filter(|&&v| v > 0.5).count();
                n += ys.len();
            }
        }
        let rate = pos as f64 / n as f64;
        assert!(rate > 0.1 && rate < 0.9, "degenerate label rate {rate}");
    }

    #[test]
    fn token_lm_targets_are_shifted_inputs() {
        let mut g = TokenLm::new(64, 8, 4, 7, 0);
        let (x, y) = g.next_batch();
        let (BatchData::I32(xs), BatchData::I32(ys)) = (x, y) else {
            panic!()
        };
        // within each row, y[t] must equal x[t+1]
        for r in 0..4 {
            for t in 0..7 {
                assert_eq!(ys[r * 8 + t], xs[r * 8 + t + 1]);
            }
        }
    }

    #[test]
    fn token_cls_labels_learnable() {
        let mut g = TokenCls::new(128, 16, 3, 64, 9, 0);
        let (_, y) = g.next_batch();
        if let BatchData::I32(ys) = y {
            // all three classes appear
            for c in 0..3 {
                assert!(ys.contains(&c), "class {c} missing");
            }
        }
    }

    #[test]
    fn seq_frames_shapes() {
        let mut g = SeqFrames::new(32, 10, 16, 4, 11, 0);
        let (x, y) = g.next_batch();
        assert_eq!(x.len(), 4 * 10 * 32);
        assert_eq!(y.len(), 4 * 10);
    }

    // skip()-vs-next_batch parity for every generator is covered by
    // `prop_dataset_skip_equals_consuming_batches` in tests/properties.rs.
}
