//! # bf16-train — Revisiting BFloat16 Training
//!
//! A production-quality reproduction of *Revisiting BFloat16 Training*
//! (Zamirai, Zhang, Aberger, De Sa; 2020): pure-16-bit-FPU deep-learning
//! training with stochastic rounding and Kahan summation on the weight
//! update, as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): quantised matmul
//!   with fp32 FMAC accumulation and fused optimizer updates.
//! * **L2** — JAX models + optimizers (`python/compile/`): per-operator
//!   output rounding, AOT-lowered to HLO text once at build time.
//! * **L3** — this crate: the PJRT runtime, the training coordinator, the
//!   synthetic data pipeline, a software numeric-format substrate, a
//!   QPyTorch-equivalent quantised-autograd simulator, the hardware cost
//!   model, and the experiment harness regenerating every paper table and
//!   figure.
//!
//! Python never runs on the training path; the `repro` binary is fully
//! self-contained once `make artifacts` has been run.
//!
//! ## Typed API
//!
//! The paper's central object — a precision policy (compute format ×
//! rounding mode × accumulator strategy) — is the typed
//! [`Policy`](precision::Policy); run parameters are assembled with the
//! [`RunSpec`](config::RunSpec) builder; the [`Runner`] facade owns the
//! PJRT engine + manifest and hands out trainers; and
//! [`Sweep`](coordinator::Sweep) fans policy × seed grids out across
//! threads:
//!
//! ```ignore
//! use bf16_train::{Mode, Policy, Runner, RunSpec, Sweep};
//!
//! let runner = Runner::open("artifacts")?;
//! // one run
//! let summary = runner.run(
//!     &RunSpec::new("dlrm-small").policy(Policy::bf16(Mode::Sr16)).steps(600),
//! )?;
//! // a threaded policy × seed grid
//! let results = Sweep::new(RunSpec::new("dlrm-small").steps(600))
//!     .policies([Policy::bf16(Mode::Fp32), Policy::bf16(Mode::Sr16)])
//!     .seeds(3)
//!     .run(&runner)?;
//! ```
//!
//! ## Deterministic intra-step parallelism
//!
//! Stochastic-rounding dither is **counter-keyed**
//! ([`util::rng::DitherKey`]): every dither word is a pure function of
//! `(seed, stream, step, tensor_id, element_index)` rather than a draw from
//! a sequential stream.  On top of that, the qsim kernels (matmul row
//! panels, elementwise tape ops, the staged SGD update) fan out over a
//! per-trainer worker pool ([`qsim::Pool`]) sized by `--intra-threads`
//! (`RunSpec::intra_threads`, TOML `train.intra_threads`; `1` = sequential
//! default, `0` = auto).  Because the dither is positional and every
//! parallel kernel is row/element-local, **training results are
//! bit-identical at every thread count** — and to the scalar
//! `Backend::Reference` oracle.  `--intra-threads` composes with the
//! sweep-level `--threads` (runs × workers); a multi-worker sweep clamps
//! auto-sized (`0`) cells back to sequential to avoid oversubscription.
//! The pool currently drives the qsim-native kernels; the PJRT session
//! path records the knob but executes its lowered programs as compiled.
//!
//! ## The native training engine
//!
//! Native (simulator) apps implement one trait — [`qsim::train::Task`] —
//! and the generic [`qsim::train::Trainer`] supplies the training loop,
//! the per-tensor optimizer bank, the held-out eval fork, the intra-step
//! pool and native `BF16CKP2` checkpoint/resume (bit-identical
//! continuation).  `qsim::dlrm`, `qsim::gpt` and `qsim::mlp` are `Task`
//! impls; see the README's "Adding a new app" walkthrough.

pub mod config;
pub mod util;
pub mod coordinator;
pub mod data;
pub mod hwcost;
pub mod metrics;
pub mod precision;
pub mod qsim;
pub mod runtime;

pub use config::{RunConfig, RunSpec, Schedule};
pub use coordinator::{run_experiment, ExpOptions, RunSummary, Sweep, SweepResults, Trainer};
pub use precision::{Format, Mode, Policy, RoundMode};
pub use qsim::train::{EvalMetrics, StepTelemetry, Task, Trainer as NativeTrainer};

use anyhow::Result;

use runtime::{Engine, Manifest};

/// Library-level facade over the PJRT runtime: owns the engine (with its
/// compiled-executable cache) and the artifact manifest, and hands out
/// [`Trainer`]s for [`RunSpec`]s.
pub struct Runner {
    engine: Engine,
    manifest: Manifest,
}

impl Runner {
    /// Open the runtime over an artifacts directory (`make artifacts`).
    pub fn open(artifacts_dir: &str) -> Result<Runner> {
        let manifest = Manifest::load(artifacts_dir)?;
        let engine = Engine::cpu()?;
        Ok(Runner { engine, manifest })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Build a live trainer for one run spec.
    pub fn trainer(&self, spec: &RunSpec) -> Result<Trainer<'_>> {
        self.trainer_for(spec.build())
    }

    /// Build a live trainer for a fully materialized config.
    pub fn trainer_for(&self, cfg: RunConfig) -> Result<Trainer<'_>> {
        Trainer::new(&self.engine, &self.manifest, cfg)
    }

    /// Run one spec end-to-end and return its summary.
    pub fn run(&self, spec: &RunSpec) -> Result<RunSummary> {
        self.trainer(spec)?.run()
    }
}
