//! # bf16-train — Revisiting BFloat16 Training
//!
//! A production-quality reproduction of *Revisiting BFloat16 Training*
//! (Zamirai, Zhang, Aberger, De Sa; 2020): pure-16-bit-FPU deep-learning
//! training with stochastic rounding and Kahan summation on the weight
//! update, as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): quantised matmul
//!   with fp32 FMAC accumulation and fused optimizer updates.
//! * **L2** — JAX models + optimizers (`python/compile/`): per-operator
//!   output rounding, AOT-lowered to HLO text once at build time.
//! * **L3** — this crate: the PJRT runtime, the training coordinator, the
//!   synthetic data pipeline, a software numeric-format substrate, a
//!   QPyTorch-equivalent quantised-autograd simulator, the hardware cost
//!   model, and the experiment harness regenerating every paper table and
//!   figure.
//!
//! Python never runs on the training path; the `repro` binary is fully
//! self-contained once `make artifacts` has been run.

pub mod config;
pub mod util;
pub mod coordinator;
pub mod data;
pub mod hwcost;
pub mod metrics;
pub mod precision;
pub mod qsim;
pub mod runtime;
