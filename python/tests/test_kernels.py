"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes and formats; every comparison is **bit-exact**
(same accumulation dtype, same single rounding on output).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats, qops
from compile.kernels import optim_kernels as ok
from compile.kernels import qmatmul as qk
from compile.kernels import ref

FMTS = [formats.BF16, formats.FP16, formats.E8M5, formats.E8M3]


def _rand(key, shape, fmt, scale=1.0):
    return formats.round_nearest(
        jax.random.normal(key, shape, jnp.float32) * scale, fmt
    )


@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    fmt_i=st.integers(0, len(FMTS) - 1),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_qmatmul_matches_ref(m, k, n, fmt_i, seed):
    fmt = FMTS[fmt_i]
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = _rand(k1, (m, k), fmt)
    b = _rand(k2, (k, n), fmt)
    out = np.asarray(qk.qmatmul_pallas(a, b, fmt))
    expect = np.asarray(ref.ref_qmatmul(a, b, fmt))
    np.testing.assert_array_equal(out, expect)


def test_qmatmul_large_tiled():
    """Shapes that actually exercise the 128-tile K loop.

    Bit-exact against the tiled oracle (same K-partial association), and
    within one ulp of the untiled oracle.
    """
    fmt = formats.BF16
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = _rand(k1, (256, 384), fmt)
    b = _rand(k2, (384, 128), fmt)
    out = np.asarray(qk.qmatmul_pallas(a, b, fmt))
    expect = np.asarray(ref.ref_qmatmul_tiled(a, b, fmt, bk=128))
    np.testing.assert_array_equal(out, expect)
    plain = np.asarray(ref.ref_qmatmul(a, b, fmt))
    np.testing.assert_allclose(out, plain, rtol=2.0**-7)


def test_qmatmul_gradients_match_qops_path():
    """Pallas backward == jnp qops backward (both rounded per operator)."""
    fmt = formats.BF16
    cfg_jnp = qops.QConfig(fmt, use_pallas=False)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    a = _rand(k1, (32, 64), fmt)
    b = _rand(k2, (64, 16), fmt)
    ct = _rand(k3, (32, 16), fmt)

    def f_pallas(a, b):
        return jnp.vdot(qk.qmatmul_pallas(a, b, fmt), ct)

    def f_jnp(a, b):
        return jnp.vdot(qops.qmatmul(a, b, cfg_jnp), ct)

    da_p, db_p = jax.grad(f_pallas, argnums=(0, 1))(a, b)
    da_j, db_j = jax.grad(f_jnp, argnums=(0, 1))(a, b)
    # The jnp path rounds the cotangent then computes unrounded vjp matmuls
    # whose outputs are rounded at the next boundary; at the leaf there is no
    # further boundary, so compare against the pallas kernel's explicitly
    # rounded output with one extra rounding applied to the jnp leaves.
    np.testing.assert_array_equal(
        np.asarray(da_p),
        np.asarray(formats.round_nearest(da_j, fmt)),
    )
    np.testing.assert_array_equal(
        np.asarray(db_p),
        np.asarray(formats.round_nearest(db_j, fmt)),
    )


@given(
    n=st.integers(1, 3000),
    fmt_i=st.integers(0, len(FMTS) - 1),
    seed=st.integers(0, 2**31 - 1),
    mu=st.sampled_from([0.0, 0.9]),
    wd=st.sampled_from([0.0, 1e-4]),
    sr=st.booleans(),
)
@settings(max_examples=25, deadline=None)
def test_sgd_kernel_matches_ref(n, fmt_i, seed, mu, wd, sr):
    fmt = FMTS[fmt_i]
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    w = _rand(keys[0], (n,), fmt)
    m = _rand(keys[1], (n,), fmt, 0.01)
    g = _rand(keys[2], (n,), fmt, 0.01)
    rb = jax.random.bits(keys[3], (n,), jnp.uint32) if sr else None
    lr = jnp.float32(0.05)
    w2, m2 = ok.sgd_update_pallas(w, m, g, lr, mu, wd, fmt, rbits=rb)
    we, me = ref.ref_sgd_update(w, m, g, lr, mu, wd, fmt, rbits=rb)
    np.testing.assert_array_equal(np.asarray(w2), np.asarray(we))
    np.testing.assert_array_equal(np.asarray(m2), np.asarray(me))


@given(n=st.integers(1, 3000), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_sgd_kahan_kernel_matches_ref(n, seed):
    fmt = formats.BF16
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    w = _rand(keys[0], (n,), fmt)
    m = _rand(keys[1], (n,), fmt, 0.01)
    c = _rand(keys[2], (n,), fmt, 1e-4)
    g = _rand(keys[3], (n,), fmt, 0.01)
    lr = jnp.float32(0.05)
    w2, m2, c2 = ok.sgd_kahan_update_pallas(w, m, c, g, lr, 0.9, 1e-4, fmt)
    we, me, ce = ref.ref_sgd_kahan_update(w, m, c, g, lr, 0.9, 1e-4, fmt)
    np.testing.assert_array_equal(np.asarray(w2), np.asarray(we))
    np.testing.assert_array_equal(np.asarray(m2), np.asarray(me))
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(ce))


@given(
    n=st.integers(1, 2000),
    seed=st.integers(0, 2**31 - 1),
    sr=st.booleans(),
)
@settings(max_examples=15, deadline=None)
def test_adamw_kernel_matches_ref(n, seed, sr):
    fmt = formats.BF16
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    w = _rand(keys[0], (n,), fmt)
    m = _rand(keys[1], (n,), fmt, 0.01)
    v = jnp.abs(_rand(keys[2], (n,), fmt, 0.001))
    g = _rand(keys[3], (n,), fmt, 0.01)
    rb = jax.random.bits(keys[4], (n,), jnp.uint32) if sr else None
    lr, d1, d2 = jnp.float32(1e-3), jnp.float32(0.1), jnp.float32(0.003)
    args = (w, m, v, g, lr, 0.9, 0.99609375, 1e-8, 0.01, d1, d2, fmt)
    w2, m2, v2 = ok.adamw_update_pallas(*args, rbits=rb)
    we, me, ve = ref.ref_adamw_update(*args, rbits=rb)
    np.testing.assert_array_equal(np.asarray(w2), np.asarray(we))
    np.testing.assert_array_equal(np.asarray(m2), np.asarray(me))
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(ve))


def test_vmem_estimate_monotone():
    small = qk.vmem_bytes(128, 128, 128)
    assert small == 4 * 3 * 128 * 128
    assert qk.vmem_bytes(64, 64, 64) < small


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
def test_qops_matmul_pallas_flag_equivalence(fmt):
    """qops.qmatmul(use_pallas=True) == qops.qmatmul(use_pallas=False)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    a = _rand(k1, (16, 24), fmt)
    b = _rand(k2, (24, 8), fmt)
    out_p = qops.qmatmul(a, b, qops.QConfig(fmt, use_pallas=True))
    out_j = qops.qmatmul(a, b, qops.QConfig(fmt, use_pallas=False))
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_j))
