"""StepBuilder / model-zoo integration: every family jits, trains, lowers.

These are the L2 shape/convergence smoke tests; the heavy per-application
convergence sweeps live on the rust side (the coordinator drives the same
lowered HLO).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import artifacts_spec as spec
from compile import models, optim
from compile.train_step import StepBuilder


def _builder(app_name, mode_name="standard16", fmt="bf16", pallas=False):
    app = spec.APPS[app_name]
    mode = optim.make_mode(mode_name, fmt)
    model = models.get(app.family, app.hparams)
    return StepBuilder(model, mode, app.optimizer, app.opt_cfg, pallas)


def _fake_batch(builder, key):
    xs, xd = builder.model.x_spec
    ys, yd = builder.model.y_spec
    kx, ky = jax.random.split(key)
    if xd == "f32":
        x = jax.random.normal(kx, xs, jnp.float32)
        if builder.model.name == "dlrm":
            # pack categorical indices into the float tail columns
            dense = int(spec.APPS["dlrm-small"].hparams["dense_dim"])
            idx = jax.random.randint(kx, (xs[0], xs[1] - dense), 0, 100)
            x = jnp.concatenate(
                [x[:, :dense], idx.astype(jnp.float32)], axis=1
            )
    else:
        x = jax.random.randint(kx, xs, 0, 100)
    if yd == "f32":
        y = (jax.random.uniform(ky, ys) > 0.5).astype(jnp.float32)
    else:
        y = jax.random.randint(ky, ys, 0, 3)
    return x, y


APPS_FAST = ["lsq", "cifar-cnn", "dlrm-small", "bert-cls", "lstm-seq"]


def _step_args(b, state, x, y, seed, lr):
    """Build the flat arg tuple (the seed input exists only for SR modes)."""
    tail = (x, y, seed, lr) if b.uses_seed else (x, y, lr)
    return (*state, *tail)


@pytest.mark.parametrize("app_name", APPS_FAST)
def test_step_runs_and_state_shapes_stable(app_name):
    b = _builder(app_name)
    init = jax.jit(b.init_fn())
    step = jax.jit(b.train_fn())
    state = list(init(0))
    n = len(state)
    assert n == len(b.param_keys) + len(b.state_keys)
    x, y = _fake_batch(b, jax.random.PRNGKey(0))
    out = step(*_step_args(b, state, x, y, 0, jnp.float32(0.01)))
    assert len(out) == n + 3
    for before, after in zip(state, out[:n]):
        assert before.shape == after.shape
    loss, metric, cancel = (float(v) for v in out[n:])
    assert np.isfinite(loss) and np.isfinite(metric)
    assert 0.0 <= cancel <= 1.0


@pytest.mark.parametrize("app_name", ["lsq", "dlrm-small"])
def test_fp32_training_decreases_loss(app_name):
    b = _builder(app_name, "fp32")
    init = jax.jit(b.init_fn())
    step = jax.jit(b.train_fn())
    state = list(init(0))
    # fixed batch: every step descends the same objective
    x, y = _fake_batch(b, jax.random.PRNGKey(1))
    first = last = None
    for t in range(30):
        out = step(*_step_args(b, state, x, y, t, jnp.float32(0.05)))
        state = list(out[: len(state)])
        loss = float(out[len(state)])
        first = loss if first is None else first
        last = loss
    assert last < first, (first, last)


def test_eval_fn_returns_preds_vector():
    b = _builder("dlrm-small", "fp32")
    init = jax.jit(b.init_fn())
    evalf = jax.jit(b.eval_fn())
    state = list(init(0))
    x, y = _fake_batch(b, jax.random.PRNGKey(2))
    loss, metric, preds = evalf(*state[: len(b.param_keys)], x, y)
    assert preds.shape == (b.model.x_spec[0][0],)
    assert np.all(np.asarray(preds) >= 0) and np.all(np.asarray(preds) <= 1)


def test_weights_stay_in_format_16bit_modes():
    """After a standard16 step, every param is bf16-representable."""
    from compile import formats

    b = _builder("lsq", "standard16")
    init = jax.jit(b.init_fn())
    step = jax.jit(b.train_fn())
    state = list(init(0))
    x, y = _fake_batch(b, jax.random.PRNGKey(3))
    out = step(*_step_args(b, state, x, y, 0, jnp.float32(0.01)))
    for i in range(len(b.param_keys)):
        w = out[i]
        np.testing.assert_array_equal(
            np.asarray(w),
            np.asarray(formats.round_nearest(w, formats.BF16)),
        )


def test_init_deterministic_per_seed():
    b = _builder("cifar-cnn")
    init = jax.jit(b.init_fn())
    flat = lambda out: np.concatenate(  # noqa: E731
        [np.asarray(t).ravel() for t in out]
    )
    a0, a1, a2 = flat(init(7)), flat(init(7)), flat(init(8))
    np.testing.assert_array_equal(a0, a1)
    assert not np.array_equal(a0, a2)


def test_pallas_and_jnp_paths_agree_on_mlp():
    """Same lowered semantics with and without the Pallas matmul kernel."""
    b_j = _builder("lsq", "standard16", pallas=False)
    b_p = _builder("lsq", "standard16", pallas=True)
    init = jax.jit(b_j.init_fn())
    state = list(init(0))
    x, y = _fake_batch(b_j, jax.random.PRNGKey(4))
    out_j = jax.jit(b_j.train_fn())(
        *_step_args(b_j, state, x, y, 0, jnp.float32(0.01))
    )
    out_p = jax.jit(b_p.train_fn())(
        *_step_args(b_p, state, x, y, 0, jnp.float32(0.01))
    )
    for a, b in zip(out_j, out_p):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_artifact_spec_complete():
    """Every default app exists and every variant has a unique name."""
    names = set()
    for app in spec.DEFAULT_APPS:
        assert app in spec.APPS
        for mode_name, fmt in spec.variants(app):
            name = spec.artifact_name(app, mode_name, fmt)
            assert name not in names
            names.add(name)
    # the paper's seven applications + theory + e2e driver
    assert len(spec.DEFAULT_APPS) == 9
    # figure sweeps present
    assert ("standard16", "fp16") in spec.variants("dlrm-small")
    assert ("srkahan16", "bf16") in spec.variants("dlrm-small")
