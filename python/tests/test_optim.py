"""Optimizer semantics per precision mode (Algorithms 2-5 + baselines).

Includes the paper's key qualitative behaviours as unit tests:
  * nearest rounding cancels small updates (the halting effect, Thm 1),
  * stochastic rounding makes progress in expectation,
  * Kahan summation accumulates sub-epsilon updates until they land,
  * mixed16/fp32 updates are exact,
  * bf16 AdamW uses β₂ = 0.99609375 (the paper's "0.997" fix).
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import formats, optim


def _mode(name, fmt="bf16"):
    return optim.make_mode(name, fmt)


def _sgd_cfg(momentum=0.0, wd=0.0):
    return optim.SgdConfig(momentum=momentum, weight_decay=wd)


def _run_sgd_steps(mode, w0, grad_value, lr, steps, seed=0):
    cfg = _sgd_cfg()
    params = {"w": jnp.asarray([w0], jnp.float32)}
    state = optim.opt_init("sgd", params, mode, cfg)
    grads = {"w": jnp.asarray([grad_value], jnp.float32)}
    key = jax.random.PRNGKey(seed)
    fracs = []
    for t in range(steps):
        key, kk = jax.random.split(key)
        params, state, frac = optim.sgd_update(
            params, state, grads, jnp.float32(lr), kk, mode, cfg
        )
        fracs.append(float(frac))
    return float(params["w"][0]), fracs


def test_nearest_rounding_halts_small_updates():
    """bf16 spacing at 1.0 is 2^-8; an update of 2^-11 must be cancelled."""
    w, fracs = _run_sgd_steps(_mode("standard16"), 1.0, 2.0**-11, 1.0, 50)
    assert w == 1.0
    assert all(f == 1.0 for f in fracs), fracs


def test_kahan_accumulates_small_updates():
    """Same tiny update: Kahan must land it after ~2^3 steps."""
    w, _ = _run_sgd_steps(_mode("kahan16"), 1.0, 2.0**-11, 1.0, 50)
    # exact descent would give 1 - 50/2048 ≈ 0.9756
    assert w < 1.0
    assert abs(w - (1.0 - 50 * 2.0**-11)) < 2.0**-8


def test_stochastic_progresses_in_expectation():
    vals = []
    for seed in range(20):
        w, _ = _run_sgd_steps(_mode("sr16"), 1.0, 2.0**-11, 1.0, 64, seed)
        vals.append(w)
    mean = np.mean(vals)
    target = 1.0 - 64 * 2.0**-11
    assert mean < 1.0
    assert abs(mean - target) < 0.01, (mean, target)


def test_fp32_and_mixed_updates_are_exact():
    for name in ("fp32", "mixed16"):
        w, fracs = _run_sgd_steps(_mode(name), 1.0, 2.0**-11, 1.0, 10)
        np.testing.assert_allclose(w, 1.0 - 10 * 2.0**-11, rtol=1e-6)
        assert all(f == 0.0 for f in fracs)


def test_srkahan_combined_progresses():
    w, _ = _run_sgd_steps(_mode("srkahan16"), 1.0, 2.0**-11, 1.0, 64)
    assert w < 1.0


def test_momentum_state_created_and_in_format():
    mode = _mode("standard16")
    cfg = _sgd_cfg(momentum=0.9)
    params = {"w": jnp.ones((4,), jnp.float32)}
    state = optim.opt_init("sgd", params, mode, cfg)
    assert "m.w" in state
    mode_k = _mode("kahan16")
    state_k = optim.opt_init("sgd", params, mode_k, _sgd_cfg(momentum=0.9))
    assert "c.w" in state_k and "m.w" in state_k


def test_beta2_bf16_substitution():
    cfg = optim.AdamWConfig(beta2=0.999)
    assert cfg.beta2_for_mode(_mode("fp32")) == 0.999
    assert cfg.beta2_for_mode(_mode("mixed16")) == 0.999
    b = cfg.beta2_for_mode(_mode("standard16"))
    assert b == 0.99609375, b  # largest bf16 below 1
    # 0.98 is bf16-representable-ish: check it stays below 1 and close
    cfg2 = optim.AdamWConfig(beta2=0.98)
    b2 = cfg2.beta2_for_mode(_mode("sr16"))
    assert 0.97 < b2 < 1.0


def test_adamw_step_moves_weights():
    mode = _mode("sr16")
    cfg = optim.AdamWConfig()
    params = {"w": jnp.ones((8,), jnp.float32)}
    state = optim.opt_init("adamw", params, mode, cfg)
    grads = {"w": jnp.full((8,), 0.1, jnp.float32)}
    params2, state2, _ = optim.adamw_update(
        params, state, grads, jnp.float32(1e-2), jax.random.PRNGKey(0), mode, cfg
    )
    assert float(jnp.max(jnp.abs(params2["w"] - params["w"]))) > 0.0
    assert float(state2["bc1"]) < 1.0


def test_cancel_frac_counts_only_nonzero_updates():
    """Zero gradients produce zero updates — not 'cancelled' ones."""
    mode = _mode("standard16")
    cfg = _sgd_cfg()
    params = {"w": jnp.ones((4,), jnp.float32)}
    state = optim.opt_init("sgd", params, mode, cfg)
    grads = {"w": jnp.zeros((4,), jnp.float32)}
    _, _, frac = optim.sgd_update(
        params, state, grads, jnp.float32(1.0), jax.random.PRNGKey(0), mode, cfg
    )
    assert float(frac) == 0.0


def test_kahan_residual_tracks_lost_mass():
    """After cancelled updates, |c| holds the lost update mass."""
    mode = _mode("kahan16")
    cfg = _sgd_cfg()
    params = {"w": jnp.asarray([1.0], jnp.float32)}
    state = optim.opt_init("sgd", params, mode, cfg)
    grads = {"w": jnp.asarray([2.0**-12], jnp.float32)}
    key = jax.random.PRNGKey(0)
    params, state, _ = optim.sgd_update(
        params, state, grads, jnp.float32(1.0), key, mode, cfg
    )
    # weight unchanged but compensation buffer remembers -u
    assert float(params["w"][0]) == 1.0
    assert abs(float(state["c.w"][0]) - 2.0**-12) < 1e-9
