"""Properties of the numeric-format emulation (L2 formats.py).

Hypothesis sweeps value ranges and formats; independent oracles:
  * jnp's own bfloat16/float16 conversions for the IEEE formats,
  * the paper's analytical bounds (|Q(u)-u| <= eps|u|, SR unbiasedness).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats

NON_FP32 = [f for f in formats.FORMATS.values() if not f.is_fp32]
E8_FORMATS = [f for f in NON_FP32 if f.exp_bits == 8]

finite_f32 = st.floats(
    allow_nan=False,
    allow_infinity=False,
    allow_subnormal=False,
    width=32,
)


@st.composite
def arrays_f32(draw, max_len=64):
    n = draw(st.integers(1, max_len))
    return np.asarray(
        draw(st.lists(finite_f32, min_size=n, max_size=n)), np.float32
    )


@pytest.mark.parametrize("fmt", NON_FP32, ids=lambda f: f.name)
@given(xs=arrays_f32())
@settings(max_examples=30, deadline=None)
def test_nearest_is_projection(fmt, xs):
    once = formats.round_nearest(jnp.asarray(xs), fmt)
    twice = formats.round_nearest(once, fmt)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


@pytest.mark.parametrize("fmt", E8_FORMATS, ids=lambda f: f.name)
@given(xs=arrays_f32())
@settings(max_examples=30, deadline=None)
def test_nearest_error_bound(fmt, xs):
    """Paper's |Q(u) - u| <= eps * |u| for in-range values.

    Values between the format's max finite value and f32 max overflow to
    inf (IEEE RNE overflow rule) — the paper's analysis explicitly ignores
    overflow, so the bound is asserted for |x| <= max_value only.
    """
    in_range = np.abs(xs) <= fmt.max_value
    xs = xs[in_range]
    q = np.asarray(formats.round_nearest(jnp.asarray(xs), fmt))
    eps = fmt.machine_eps
    assert np.all(np.abs(q - xs) <= eps * np.abs(xs) + 1e-45)


def test_bf16_matches_jnp_cast():
    """Independent oracle: jnp bfloat16 conversion is RNE."""
    rng = np.random.RandomState(0)
    xs = (rng.randn(4096) * 10.0 ** rng.randint(-30, 30, 4096)).astype(
        np.float32
    )
    ours = np.asarray(formats.round_nearest(jnp.asarray(xs), formats.BF16))
    theirs = np.asarray(
        jnp.asarray(xs).astype(jnp.bfloat16).astype(jnp.float32)
    )
    np.testing.assert_array_equal(ours, theirs)


def test_fp16_matches_jnp_cast_for_normals():
    """fp16 oracle restricted to the normal range (we document FTZ)."""
    rng = np.random.RandomState(1)
    xs = (rng.randn(4096) * 10.0 ** rng.uniform(-4, 4, 4096)).astype(
        np.float32
    )
    xs = xs[np.abs(xs) >= 6.2e-5]  # above fp16 min normal (with margin)
    xs = xs[np.abs(xs) < 60000.0]
    ours = np.asarray(formats.round_nearest(jnp.asarray(xs), formats.FP16))
    theirs = np.asarray(
        jnp.asarray(xs).astype(jnp.float16).astype(jnp.float32)
    )
    np.testing.assert_array_equal(ours, theirs)


def test_fp16_overflow_and_ftz():
    xs = jnp.asarray([1e6, -1e6, 70000.0, 1e-8, -1e-8, 0.0], jnp.float32)
    q = np.asarray(formats.round_nearest(xs, formats.FP16))
    assert q[0] == np.inf and q[1] == -np.inf and q[2] == np.inf
    assert q[3] == 0.0 and q[4] == 0.0 and q[5] == 0.0


@pytest.mark.parametrize("fmt", NON_FP32, ids=lambda f: f.name)
def test_stochastic_rounds_to_neighbours(fmt):
    """SR output is always one of the two neighbouring representables."""
    rng = np.random.RandomState(2)
    xs = (rng.randn(2048) * 10.0 ** rng.randint(-8, 8, 2048)).astype(
        np.float32
    )
    key = jax.random.PRNGKey(0)
    rbits = jax.random.bits(key, xs.shape, jnp.uint32)
    q = np.asarray(formats.round_stochastic(jnp.asarray(xs), fmt, rbits))
    down = np.asarray(
        formats.round_stochastic(
            jnp.asarray(xs), fmt, jnp.zeros(xs.shape, jnp.uint32)
        )
    )  # rbits=0 == truncation toward -|mantissa| (round down in magnitude)
    if fmt.exp_bits == 8:
        up_candidates = np.asarray(
            formats.round_stochastic(
                jnp.asarray(xs),
                fmt,
                jnp.full(xs.shape, (1 << fmt.drop_bits) - 1, jnp.uint32),
            )
        )
        ok = (q == down) | (q == up_candidates)
        assert np.all(ok)


def test_stochastic_is_unbiased():
    """Mean over many dither draws converges to the exact value."""
    x = jnp.full((20000,), 1.0 + 1.0 / 512.0, jnp.float32)  # mid-interval
    key = jax.random.PRNGKey(3)
    rbits = jax.random.bits(key, x.shape, jnp.uint32)
    q = np.asarray(formats.round_stochastic(x, formats.BF16, rbits))
    # bf16 neighbours of 1.001953125 are 1.0 and 1.0078125; expect 1/4 up.
    mean = q.mean()
    assert abs(mean - (1.0 + 1.0 / 512.0)) < 2e-4, mean
    frac_up = (q > 1.0).mean()
    assert abs(frac_up - 0.25) < 0.02, frac_up


def test_round_nearest_py_matches_jnp():
    rng = np.random.RandomState(4)
    xs = (rng.randn(512) * 10.0 ** rng.randint(-20, 20, 512)).astype(
        np.float32
    )
    for fmt in NON_FP32:
        ours = np.asarray([formats.round_nearest_py(float(x), fmt) for x in xs], np.float32)
        theirs = np.asarray(formats.round_nearest(jnp.asarray(xs), fmt))
        np.testing.assert_array_equal(ours, theirs, err_msg=fmt.name)


def test_machine_eps_convention():
    """eps = 2^-(m+1): 1 + eps must round back to 1, 1 + 2 eps must not."""
    for fmt in E8_FORMATS:
        eps = fmt.machine_eps
        one_plus = jnp.asarray(1.0 + eps * 0.99, jnp.float32)
        q = float(formats.round_nearest(one_plus, fmt))
        assert q == 1.0, fmt.name
        q2 = float(
            formats.round_nearest(jnp.asarray(1.0 + 2.5 * eps, jnp.float32), fmt)
        )
        assert q2 > 1.0, fmt.name


def test_nan_inf_pass_through():
    xs = jnp.asarray([np.nan, np.inf, -np.inf], jnp.float32)
    q = np.asarray(formats.round_nearest(xs, formats.BF16))
    assert np.isnan(q[0]) and q[1] == np.inf and q[2] == -np.inf
