"""Application registry: the paper's seven workloads (scaled) + theory model.

Each application pins a model family, its hyperparameters, the optimizer and
its config — mirroring Appendix C.1 of the paper, scaled so that the full
pipeline runs on a CPU PJRT backend (the paper itself ran a *simulator* on
V100s; our substitution table is DESIGN.md §4).

``modes_for(app)`` lists the precision modes lowered for that app.  The
sub-16-bit and fp16 format sweeps (Figures 10 & 12) are attached to the
DLRM-Kaggle application, exactly as in the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from . import optim


@dataclasses.dataclass(frozen=True)
class App:
    name: str
    family: str
    hparams: dict
    optimizer: str
    opt_cfg: object
    paper_ref: str


def _sgd(momentum=0.9, wd=0.0):
    return optim.SgdConfig(momentum=momentum, weight_decay=wd)


def _adamw(b1=0.9, b2=0.999, wd=0.01):
    return optim.AdamWConfig(beta1=b1, beta2=b2, weight_decay=wd)


APPS: Dict[str, App] = {}


def _app(name, family, hparams, optimizer, opt_cfg, paper_ref):
    APPS[name] = App(name, family, hparams, optimizer, opt_cfg, paper_ref)


# -- Theory validation (Section 3.1, Figure 2) ------------------------------
_app(
    "lsq",
    "mlp",
    {"task": "regression", "in_dim": 10, "hidden": [], "batch": 1},
    "sgd",
    _sgd(momentum=0.0, wd=0.0),
    "Fig 2 / Thm 1: 10-dim least squares, batch 1, lr 0.01",
)

# -- ResNet-18 / CIFAR10  →  cnn-small on synthetic 3x32x32 ------------------
_app(
    "cifar-cnn",
    "cnn",
    {
        "channels": [16, 32, 64],
        "num_classes": 10,
        "batch": 32,
        "image": 32,
        "blocks": 1,
    },
    "sgd",
    _sgd(momentum=0.9, wd=5e-4),
    "Table 3/4 row ResNet-18/CIFAR10",
)

# -- ResNet-50 / ImageNet  →  cnn-large -------------------------------------
_app(
    "imagenet-cnn",
    "cnn",
    {
        "channels": [32, 64, 128],
        "num_classes": 100,
        "batch": 32,
        "image": 32,
        "blocks": 2,
    },
    "sgd",
    _sgd(momentum=0.9, wd=1e-4),
    "Table 4 row ResNet-50/ImageNet",
)

# -- DLRM / Criteo Kaggle ----------------------------------------------------
_app(
    "dlrm-small",
    "dlrm",
    {
        "num_tables": 8,
        "table_size": 1000,
        "embed_dim": 16,
        "dense_dim": 13,
        "bottom_mlp": [64, 16],
        "top_mlp": [64, 32],
        "batch": 128,
    },
    "sgd",
    _sgd(momentum=0.0, wd=0.0),
    "Table 3/4 row DLRM/Kaggle; Figs 5, 9, 10, 11, 12",
)

# -- DLRM / Criteo Terabyte ---------------------------------------------------
_app(
    "dlrm-large",
    "dlrm",
    {
        "num_tables": 16,
        "table_size": 4000,
        "embed_dim": 32,
        "dense_dim": 13,
        "bottom_mlp": [128, 64, 32],
        "top_mlp": [128, 64],
        "batch": 256,
    },
    "sgd",
    _sgd(momentum=0.0, wd=0.0),
    "Table 4 row DLRM/Terabyte",
)

# -- BERT-Base / MNLI  →  tiny encoder classifier -----------------------------
_app(
    "bert-cls",
    "transformer",
    {
        "task": "classification",
        "vocab": 512,
        "dim": 64,
        "heads": 4,
        "layers": 2,
        "seq": 32,
        "num_classes": 3,
        "batch": 32,
    },
    "adamw",
    _adamw(b1=0.9, b2=0.999, wd=0.01),
    "Fig 1 / Table 3/4 row BERT/MNLI",
)

# -- BERT / Wiki103  →  tiny causal LM ----------------------------------------
_app(
    "bert-lm",
    "transformer",
    {
        "task": "lm",
        "vocab": 512,
        "dim": 64,
        "heads": 4,
        "layers": 2,
        "seq": 64,
        "batch": 16,
    },
    "adamw",
    _adamw(b1=0.9, b2=0.98, wd=0.01),
    "Table 4 row BERT/Wiki103 (PPL)",
)

# -- DeepSpeech2 / LibriSpeech  →  BiLSTM tagger ------------------------------
_app(
    "lstm-seq",
    "lstm",
    {
        "in_dim": 32,
        "hidden": 64,
        "num_classes": 16,
        "seq": 32,
        "batch": 16,
        "bidirectional": True,
    },
    "sgd",
    _sgd(momentum=0.9, wd=1e-5),
    "Table 4 row DeepSpeech2/LibriSpeech (WER proxy = 1-token-acc)",
)

# -- End-to-end example: transformer LM, size configurable -------------------
for size, (dim, layers, heads, seq, vocab, batch) in {
    "tiny": (128, 4, 4, 64, 1024, 16),
    "small": (256, 6, 8, 128, 2048, 8),
    "100m": (768, 12, 12, 128, 32768, 8),
}.items():
    _app(
        f"gpt-{size}",
        "transformer",
        {
            "task": "lm",
            "vocab": vocab,
            "dim": dim,
            "heads": heads,
            "layers": layers,
            "seq": seq,
            "batch": batch,
        },
        "adamw",
        _adamw(b1=0.9, b2=0.98, wd=0.01),
        "End-to-end driver (examples/train_transformer.rs)",
    )


BASE_MODES = ["fp32", "standard16", "mixed16", "sr16", "kahan16"]
EXTRA_MODES = {
    # Figure 11 (combined) lowered where the paper shows it.
    "dlrm-small": ["srkahan16"],
    "cifar-cnn": ["srkahan16"],
    "bert-cls": ["srkahan16"],
}
# Figure 10 & 12 format sweeps, attached to DLRM-Kaggle.
FMT_SWEEP_APP = "dlrm-small"
FMT_SWEEP = [
    ("fp16", ["standard16", "sr16", "kahan16"]),
    ("e8m5", ["standard16", "sr16", "kahan16"]),
    ("e8m3", ["standard16", "sr16", "kahan16"]),
    ("e8m1", ["standard16", "sr16", "kahan16"]),
]

# Default artifact set (the big gpt sizes are opt-in via --filter).
DEFAULT_APPS = [
    "lsq",
    "cifar-cnn",
    "imagenet-cnn",
    "dlrm-small",
    "dlrm-large",
    "bert-cls",
    "bert-lm",
    "lstm-seq",
    "gpt-tiny",
]


def variants(app_name: str) -> List[Tuple[str, str]]:
    """All (mode, fmt) pairs lowered for an app."""
    out = [(m, "bf16") for m in BASE_MODES]
    out += [(m, "bf16") for m in EXTRA_MODES.get(app_name, [])]
    if app_name == FMT_SWEEP_APP:
        for fmt, modes in FMT_SWEEP:
            out += [(m, fmt) for m in modes]
    return out


def artifact_name(app: str, mode: str, fmt: str) -> str:
    return f"{app}__{mode}" if fmt == "bf16" else f"{app}__{mode}-{fmt}"
