"""AOT compiler: lower every (application × precision-mode) step to HLO text.

Emits, per artifact:    artifacts/<name>.train.hlo.txt
                        artifacts/<name>.eval.hlo.txt
                        artifacts/<name>.init.hlo.txt
plus a single           artifacts/manifest.json
and shared test vectors artifacts/golden_formats.json  (rust↔python parity).

HLO **text** is the interchange format — the image's xla_extension 0.5.1
rejects jax>=0.5 serialized HloModuleProtos (64-bit instruction ids); the
text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts \
            [--filter REGEX] [--force]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import re
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import artifacts_spec as spec
from . import formats, models, optim
from .train_step import StepBuilder


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _entry_param_count(hlo_text: str) -> int:
    """Number of parameters of the ENTRY computation in HLO text."""
    entry = hlo_text[hlo_text.index("ENTRY ") :]
    return entry.count(" parameter(")


def lower_app(app: spec.App, mode_name: str, fmt_name: str, use_pallas: bool):
    """Build (train_hlo, eval_hlo, init_hlo, manifest_entry) for one variant."""
    mode = optim.make_mode(mode_name, fmt_name)
    model = models.get(app.family, app.hparams)
    builder = StepBuilder(model, mode, app.optimizer, app.opt_cfg, use_pallas)

    train_hlo = to_hlo_text(
        jax.jit(builder.train_fn()).lower(*builder.example_args())
    )
    eval_hlo = to_hlo_text(
        jax.jit(builder.eval_fn()).lower(*builder.eval_example_args())
    )
    init_hlo = to_hlo_text(
        jax.jit(builder.init_fn()).lower(jax.ShapeDtypeStruct((), jnp.int32))
    )
    ins, outs, eval_ins = builder.signature()
    # Guard: jax prunes unused arguments during lowering; the manifest and
    # the executable signature must agree or the rust runtime mis-binds.
    got_train = _entry_param_count(train_hlo)
    assert got_train == len(ins), (
        f"{app.name} {mode_name}-{fmt_name}: train HLO has {got_train} "
        f"params, manifest expects {len(ins)} — an input was pruned"
    )
    got_eval = _entry_param_count(eval_hlo)
    assert got_eval == len(eval_ins), (
        f"{app.name}: eval HLO has {got_eval} params, expected {len(eval_ins)}"
    )
    xs, _ = model.x_spec
    entry = {
        "app": app.name,
        "mode": mode_name,
        "fmt": fmt_name,
        "family": app.family,
        "optimizer": app.optimizer,
        "metric_name": model.metric_name,
        "paper_ref": app.paper_ref,
        "batch": int(xs[0]),
        "hparams": {
            k: v for k, v in app.hparams.items() if isinstance(v, (int, str))
        },
        "train_inputs": ins,
        "train_outputs": outs,
        "eval_inputs": eval_ins,
        "eval_outputs": [
            {"role": "loss", "key": "", "shape": [], "dtype": "f32"},
            {"role": "metric", "key": "", "shape": [], "dtype": "f32"},
            {
                "role": "preds",
                "key": "",
                "shape": [int(xs[0])],
                "dtype": "f32",
            },
        ],
        "num_params": len(builder.param_keys),
        "num_opt_state": len(builder.state_keys),
        "param_elements": int(
            sum(
                int(np.prod(s)) if s else 1
                for s in builder.param_shapes.values()
            )
        ),
    }
    return train_hlo, eval_hlo, init_hlo, entry


def golden_vectors() -> dict:
    """Shared rounding test vectors for bit-exact rust↔python parity."""
    rng = np.random.RandomState(0)
    xs = np.concatenate(
        [
            (rng.randn(64) * 10.0 ** rng.randint(-20, 20, 64)).astype(
                np.float32
            ),
            np.array(
                [0.0, -0.0, 1.0, -1.0, 0.1, 1e-30, 1e30, 65504.0, 3.14159],
                dtype=np.float32,
            ),
        ]
    )
    rbits = rng.randint(0, 2**32, size=xs.shape, dtype=np.uint64).astype(
        np.uint32
    )
    out = {"inputs_bits": [int(b) for b in xs.view(np.uint32)], "formats": {}}
    for name, fmt in formats.FORMATS.items():
        if fmt.is_fp32:
            continue
        nearest = np.asarray(formats.round_nearest(jnp.asarray(xs), fmt))
        stoch = np.asarray(
            formats.round_stochastic(jnp.asarray(xs), fmt, jnp.asarray(rbits))
        )
        out["formats"][name] = {
            "rbits": [int(b) for b in rbits],
            "nearest_bits": [int(b) for b in nearest.view(np.uint32)],
            "stochastic_bits": [int(b) for b in stoch.view(np.uint32)],
        }
    return out


def _hash_inputs() -> str:
    """Hash of the compile-path sources; changes force a rebuild."""
    h = hashlib.sha256()
    root = pathlib.Path(__file__).parent
    for p in sorted(root.rglob("*.py")):
        h.update(p.read_bytes())
    return h.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--filter",
        default="",
        help="regex on artifact names; default = spec.DEFAULT_APPS set",
    )
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--pallas",
        action="store_true",
        help="route matmuls through the Pallas L1 kernel (slower lowering)",
    )
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    wanted = []
    for app_name in spec.APPS:
        if not args.filter and app_name not in spec.DEFAULT_APPS:
            continue
        for mode_name, fmt_name in spec.variants(app_name):
            name = spec.artifact_name(app_name, mode_name, fmt_name)
            if args.filter and not re.search(args.filter, name):
                continue
            wanted.append((name, app_name, mode_name, fmt_name))

    stamp = _hash_inputs() + ("+pallas" if args.pallas else "")
    stamp_file = out / "inputs.hash"
    manifest_file = out / "manifest.json"
    if (
        not args.force
        and stamp_file.exists()
        and stamp_file.read_text() == stamp
        and manifest_file.exists()
    ):
        have = {
            e["name"]
            for e in json.loads(manifest_file.read_text())["artifacts"]
        }
        if {n for n, *_ in wanted} <= have:
            print(f"artifacts up to date ({len(have)} entries)")
            return

    # merge with any existing manifest so filtered rebuilds don't clobber
    # previously-built entries (their HLO files are still on disk).
    existing = []
    if manifest_file.exists():
        try:
            old = json.loads(manifest_file.read_text())["artifacts"]
            rebuilt = {n for n, *_ in wanted}
            existing = [
                e
                for e in old
                if e["name"] not in rebuilt
                and (out / e["files"]["train"]).exists()
            ]
        except (KeyError, ValueError):
            existing = []
    manifest = {"artifacts": existing, "stamp": stamp}
    for i, (name, app_name, mode_name, fmt_name) in enumerate(wanted):
        print(
            f"[{i + 1}/{len(wanted)}] lowering {name}",
            file=sys.stderr,
            flush=True,
        )
        train_hlo, eval_hlo, init_hlo, entry = lower_app(
            spec.APPS[app_name], mode_name, fmt_name, args.pallas
        )
        (out / f"{name}.train.hlo.txt").write_text(train_hlo)
        (out / f"{name}.eval.hlo.txt").write_text(eval_hlo)
        (out / f"{name}.init.hlo.txt").write_text(init_hlo)
        entry["name"] = name
        entry["files"] = {
            "train": f"{name}.train.hlo.txt",
            "eval": f"{name}.eval.hlo.txt",
            "init": f"{name}.init.hlo.txt",
        }
        manifest["artifacts"].append(entry)

    (out / "golden_formats.json").write_text(json.dumps(golden_vectors()))
    manifest_file.write_text(json.dumps(manifest, indent=1))
    stamp_file.write_text(stamp)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {out}")


if __name__ == "__main__":
    main()
