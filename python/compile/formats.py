"""Numeric-format emulation library (L2).

This module implements the paper's FMAC-output rounding semantics as pure
jnp bit manipulation on float32 storage:

  * every emulated format is a *value subset of float32* — a sign bit, the
    full 8-bit f32 exponent range clamped to the format's exponent range,
    and ``mant_bits`` of the f32 mantissa.  Keeping storage in f32 lets the
    AOT-lowered HLO be executed by any PJRT backend and keeps the rust side
    format-agnostic.
  * ``round_nearest``   — round-to-nearest-even on the mantissa boundary
    (the standard FMAC output mode, Section 2 of the paper).
  * ``round_stochastic``— the hardware algorithm from Appendix B.1: add
    uniform random bits to the dropped mantissa positions, then truncate.
  * formats with fewer exponent bits than f32 (fp16 = e5m10) additionally
    model overflow→±inf and underflow→0 (flush-to-zero).  The paper's
    Figure 12 degradation for Float16 is driven exactly by this reduced
    dynamic range.

The rust crate mirrors these bit-level semantics in ``rust/src/precision``;
``aot.py`` emits shared golden vectors so the two implementations are tested
for bit-exact parity.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Format:
    """A binary floating-point format emulated inside float32 storage."""

    name: str
    exp_bits: int
    mant_bits: int

    @property
    def is_fp32(self) -> bool:
        return self.exp_bits == 8 and self.mant_bits == 23

    @property
    def drop_bits(self) -> int:
        """Number of f32 mantissa bits dropped by this format."""
        return 23 - self.mant_bits

    @property
    def max_exp(self) -> int:
        """Maximum unbiased exponent of a finite value."""
        return 2 ** (self.exp_bits - 1) - 1

    @property
    def min_exp(self) -> int:
        """Minimum unbiased exponent of a *normal* value."""
        return -(2 ** (self.exp_bits - 1) - 2)

    @property
    def machine_eps(self) -> float:
        """Machine epsilon (distance from 1.0 to the next value) / 2.

        Matches the paper's epsilon convention: |Q(u) - u| <= eps * |u|.
        """
        return 2.0 ** (-self.mant_bits - 1)

    @property
    def max_value(self) -> float:
        return float((2.0 - 2.0 ** (-self.mant_bits)) * 2.0**self.max_exp)

    @property
    def min_normal(self) -> float:
        return float(2.0**self.min_exp)

    @property
    def total_bits(self) -> int:
        return 1 + self.exp_bits + self.mant_bits


FP32 = Format("fp32", 8, 23)
BF16 = Format("bf16", 8, 7)
FP16 = Format("fp16", 5, 10)
# Sub-16-bit formats from Figure 10: BFloat-style 8 exponent bits, reduced
# mantissa.  e8m5 = "14-bit", e8m3 = "12-bit", e8m1 = "10-bit".
E8M5 = Format("e8m5", 8, 5)
E8M3 = Format("e8m3", 8, 3)
E8M1 = Format("e8m1", 8, 1)

FORMATS = {f.name: f for f in (FP32, BF16, FP16, E8M5, E8M3, E8M1)}


def _bitcast_u32(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)


def _bitcast_f32(u: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(u.astype(jnp.uint32), jnp.float32)


def _clamp_range(y: jnp.ndarray, x: jnp.ndarray, fmt: Format) -> jnp.ndarray:
    """Apply the format's dynamic range to rounded values ``y``.

    ``x`` is the pre-rounding input (used to preserve NaN/inf signs).
    Overflow rounds to ±inf (IEEE round-to-nearest overflow rule);
    magnitudes below the smallest normal flush to zero (FTZ — documented
    substitution for subnormal support; see DESIGN.md §4).
    """
    if fmt.exp_bits >= 8:
        return y
    absy = jnp.abs(y)
    inf = jnp.asarray(jnp.inf, jnp.float32)
    y = jnp.where(absy > fmt.max_value, jnp.copysign(inf, y), y)
    # FTZ preserves the sign (IEEE signed zero)
    y = jnp.where(absy < fmt.min_normal, jnp.copysign(jnp.zeros_like(y), y), y)
    return y


def round_nearest(x: jnp.ndarray, fmt: Format) -> jnp.ndarray:
    """Round-to-nearest-even onto ``fmt``'s value set (f32 storage).

    Bit algorithm: add ``half - 1 + lsb`` to the f32 pattern, then clear the
    dropped mantissa bits.  The carry correctly propagates into the exponent
    when the mantissa rolls over (e.g. 1.9999 -> 2.0).  NaN/inf pass through.
    """
    x = x.astype(jnp.float32)
    if fmt.is_fp32:
        return x
    if fmt.exp_bits == 8 and fmt.mant_bits == 7:
        # bf16: XLA's native convert IS round-to-nearest-even and is
        # bit-identical to the integer algorithm below (verified over 100k
        # random + special values).  Using the native op keeps the lowered
        # graphs small — the bitcast chains blow up XLA CPU compile time on
        # transformer-scale models (EXPERIMENTS.md §Perf L2).
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    drop = fmt.drop_bits
    u = _bitcast_u32(x)
    half = jnp.uint32(1 << (drop - 1))
    one = jnp.uint32(1)
    lsb = (u >> drop) & one
    rounded = (u + (half - one + lsb)) & jnp.uint32((0xFFFFFFFF << drop) & 0xFFFFFFFF)
    y = _bitcast_f32(rounded)
    y = jnp.where(jnp.isfinite(x), y, x)
    return _clamp_range(y, x, fmt)


def round_stochastic(
    x: jnp.ndarray, fmt: Format, rbits: jnp.ndarray
) -> jnp.ndarray:
    """Stochastic rounding onto ``fmt`` using pre-drawn random bits.

    ``rbits`` must be uint32 of the same shape as ``x``; only the low
    ``drop_bits`` bits are used.  This is the shift-register hardware scheme
    of Appendix B.1: add random bits below the kept mantissa, truncate.
    P(round up) == fraction of the dropped tail — exactly the paper's
    (a - a_l)/(a_u - a_l).
    """
    x = x.astype(jnp.float32)
    if fmt.is_fp32:
        return x
    drop = fmt.drop_bits
    u = _bitcast_u32(x)
    noise = rbits.astype(jnp.uint32) & jnp.uint32((1 << drop) - 1)
    rounded = (u + noise) & jnp.uint32((0xFFFFFFFF << drop) & 0xFFFFFFFF)
    y = _bitcast_f32(rounded)
    y = jnp.where(jnp.isfinite(x), y, x)
    return _clamp_range(y, x, fmt)


def quantize(
    x: jnp.ndarray,
    fmt: Format,
    mode: str = "nearest",
    rbits: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Round ``x`` onto ``fmt``'s value set with the given rounding mode."""
    if mode == "nearest":
        return round_nearest(x, fmt)
    if mode == "stochastic":
        if rbits is None:
            raise ValueError("stochastic rounding requires rbits")
        return round_stochastic(x, fmt, rbits)
    raise ValueError(f"unknown rounding mode {mode!r}")


def random_bits_like(key: jax.Array, x: jnp.ndarray) -> jnp.ndarray:
    """Draw uint32 dithering bits shaped like ``x`` (threefry)."""
    return jax.random.bits(key, shape=x.shape, dtype=jnp.uint32)


def round_nearest_py(x: float, fmt: Format) -> float:
    """Pure-python round-to-nearest-even (for *static* hyperparameters).

    Bit-identical to :func:`round_nearest`; used where tracing must not
    occur (e.g. computing the bf16-representable β₂ in optim.py).
    """
    import struct

    if fmt.is_fp32:
        return float(np_f32(x))
    u = struct.unpack("<I", struct.pack("<f", np_f32(x)))[0]
    drop = fmt.drop_bits
    half = 1 << (drop - 1)
    lsb = (u >> drop) & 1
    rounded = (u + half - 1 + lsb) & ((0xFFFFFFFF << drop) & 0xFFFFFFFF)
    y = struct.unpack("<f", struct.pack("<I", rounded & 0xFFFFFFFF))[0]
    if fmt.exp_bits < 8:
        if abs(y) > fmt.max_value:
            y = float("inf") if y > 0 else float("-inf")
        elif abs(y) < fmt.min_normal:
            import math

            y = math.copysign(0.0, y)
    return y


def np_f32(x: float) -> float:
    """Round a python float to f32 precision (via struct round-trip)."""
    import struct

    return struct.unpack("<f", struct.pack("<f", x))[0]
