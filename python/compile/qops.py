"""Quantised compute-graph operators (L2).

The paper models training on a hypothetical 16-bit-FPU accelerator: every
compute-graph operator reads 16-bit inputs, accumulates in a 32-bit FMAC
accumulator, and rounds its output back to 16 bits (nearest rounding).  We
reproduce those semantics in JAX:

  * the *values* flow as float32 (so fp32 hardware does the accumulation —
    exactly the FMAC's wide accumulator), and
  * ``qout`` rounds each operator's output onto the emulated format.

``qout`` is a ``jax.custom_vjp`` so that the *backward* pass obeys the same
rule: every cotangent crossing an operator boundary is rounded too.  Weights
are wrapped with ``qparam`` at their point of use, which (a) models the FMAC
reading the weight through a 16-bit port and (b) makes the weight gradient
pass through a rounding boundary before reaching the optimizer.

When ``QConfig.use_pallas`` is set, 2-D matmuls route through the Pallas
kernel in ``kernels/qmatmul.py`` (interpret=True), which implements the same
tile-accumulate-round schedule explicitly; it is numerically identical to the
jnp path and is validated against ``kernels/ref.py`` in pytest.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import formats
from .formats import Format


@dataclasses.dataclass(frozen=True)
class QConfig:
    """Precision configuration for forward/backward compute.

    compute    — format that operator outputs are rounded to.
    use_pallas — route 2-D matmuls through the L1 Pallas kernel.
    """

    compute: Format
    use_pallas: bool = False

    @property
    def exact(self) -> bool:
        return self.compute.is_fp32


FP32_CFG = QConfig(formats.FP32)
BF16_CFG = QConfig(formats.BF16)


# --------------------------------------------------------------------------
# Rounding boundary with rounded backward pass.
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _qcast(x, exp_bits: int, mant_bits: int):
    fmt = Format("q", exp_bits, mant_bits)
    return formats.round_nearest(x, fmt)


def _qcast_fwd(x, exp_bits, mant_bits):
    return _qcast(x, exp_bits, mant_bits), None


def _qcast_bwd(exp_bits, mant_bits, _res, g):
    fmt = Format("q", exp_bits, mant_bits)
    return (formats.round_nearest(g, fmt),)


_qcast.defvjp(_qcast_fwd, _qcast_bwd)


def qout(x: jnp.ndarray, cfg: QConfig) -> jnp.ndarray:
    """Round an operator output onto the compute format (rounded VJP)."""
    if cfg.exact:
        return x
    return _qcast(x, cfg.compute.exp_bits, cfg.compute.mant_bits)


def qparam(w: jnp.ndarray, cfg: QConfig) -> jnp.ndarray:
    """Read a parameter through a 16-bit FMAC input port.

    Identity-valued when the parameter is already in-format (the 16-bit-FPU
    modes), a true cast in the 32-bit-weights ablation / mixed mode.  Either
    way the weight *gradient* is rounded on its way back.
    """
    return qout(w, cfg)


def qdata(x: jnp.ndarray, cfg: QConfig) -> jnp.ndarray:
    """Ingest input data into the compute format (no gradient path)."""
    if cfg.exact:
        return x
    return formats.round_nearest(x, cfg.compute)


# --------------------------------------------------------------------------
# Operators.  Each accumulates in fp32 and rounds its own output.
# --------------------------------------------------------------------------


def qmatmul(a: jnp.ndarray, b: jnp.ndarray, cfg: QConfig) -> jnp.ndarray:
    """Quantised matmul: bf16-valued inputs, fp32 accumulate, rounded out."""
    if cfg.use_pallas and a.ndim == 2 and b.ndim == 2 and not cfg.exact:
        from .kernels import qmatmul as qk

        return qk.qmatmul_pallas(a, b, cfg.compute)
    return qout(jnp.matmul(a, b), cfg)


def qlinear(x, w, b, cfg: QConfig):
    """x @ w + b with per-operator rounding (two FMAC ops)."""
    y = qmatmul(x, qparam(w, cfg), cfg)
    if b is not None:
        y = qout(y + qparam(b, cfg), cfg)
    return y


def qadd(a, b, cfg: QConfig):
    return qout(a + b, cfg)


def qmul(a, b, cfg: QConfig):
    return qout(a * b, cfg)


def qrelu(x, cfg: QConfig):
    # Sign selection introduces no rounding error; kept rounded for uniform
    # operator semantics.
    return qout(jax.nn.relu(x), cfg)


def qgelu(x, cfg: QConfig):
    return qout(jax.nn.gelu(x), cfg)


def qsigmoid(x, cfg: QConfig):
    return qout(jax.nn.sigmoid(x), cfg)


def qtanh(x, cfg: QConfig):
    return qout(jnp.tanh(x), cfg)


def qsoftmax(x, cfg: QConfig, axis: int = -1):
    # Fused softmax: one operator, one output rounding — mirrors the fused
    # activation/normalisation convention of the paper's simulator (§4 fn 4).
    return qout(jax.nn.softmax(x, axis=axis), cfg)


def qlayernorm(x, gamma, beta, cfg: QConfig, eps: float = 1e-5):
    """Fused layer norm (single output rounding, per simulator convention)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * qparam(gamma, cfg) + qparam(beta, cfg)
    return qout(y, cfg)


def qconv2d(x, w, cfg: QConfig, stride: int = 1, padding: str = "SAME"):
    """NCHW conv with fp32 FMAC accumulate and a single output rounding."""
    y = jax.lax.conv_general_dilated(
        x,
        qparam(w, cfg),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return qout(y, cfg)


def qembed(table, idx, cfg: QConfig):
    """Embedding lookup: a gather is a memory op; values are already
    in-format but the gradient scatter output is rounded (via qparam)."""
    return jnp.take(qparam(table, cfg), idx, axis=0)


def qmean(x, cfg: QConfig, axis=None):
    return qout(jnp.mean(x, axis=axis), cfg)


def qsum(x, cfg: QConfig, axis=None):
    return qout(jnp.sum(x, axis=axis), cfg)


# --------------------------------------------------------------------------
# Losses (fused: one rounding at the scalar output).
# --------------------------------------------------------------------------


def mse_loss(pred, target, cfg: QConfig):
    d = qout(pred - target, cfg)
    return qmean(d * d, cfg) * 0.5


def bce_with_logits(logits, labels, cfg: QConfig):
    z = qout(jax.nn.log_sigmoid(logits), cfg)
    nz = qout(jax.nn.log_sigmoid(-logits), cfg)
    return qmean(-(labels * z + (1.0 - labels) * nz), cfg)


def softmax_xent(logits, labels, cfg: QConfig):
    """Cross entropy with integer labels; fused log-softmax."""
    logp = qout(jax.nn.log_softmax(logits, axis=-1), cfg)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return qmean(nll, cfg)
