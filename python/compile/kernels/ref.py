"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every kernel must match its oracle **bit-exactly**: both sides use fp32
accumulation and the same single-rounding-on-output rule, so there is no
tolerance window — `pytest` asserts equality of bit patterns.
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import formats
from ..formats import Format


def ref_qmatmul(a: jnp.ndarray, b: jnp.ndarray, fmt: Format) -> jnp.ndarray:
    """fp32-accumulated matmul with one nearest rounding on the output."""
    return formats.round_nearest(
        jnp.matmul(a, b, preferred_element_type=jnp.float32), fmt
    )


def ref_qmatmul_tiled(
    a: jnp.ndarray, b: jnp.ndarray, fmt: Format, bk: int
) -> jnp.ndarray:
    """Oracle matching the kernel's K-tile accumulation order exactly.

    When K > the kernel's K block, partial tile products are accumulated
    sequentially in fp32; fp32 addition is non-associative, so the oracle
    must follow the same association to stay bit-exact.
    """
    k = a.shape[1]
    acc = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
    for kk in range(0, k, bk):
        acc = acc + jnp.matmul(
            a[:, kk : kk + bk],
            b[kk : kk + bk, :],
            preferred_element_type=jnp.float32,
        )
    return formats.round_nearest(acc, fmt)


def ref_sgd_update(w, m, g, lr, mu, wd, fmt: Format, rbits=None):
    """Algorithm 2 inner ops (momentum SGD, nearest-rounded ops).

    Returns (w', m').  If ``rbits`` is given the weight-update subtraction is
    stochastically rounded (the ⊖ operator); otherwise nearest.
    """
    r = lambda x: formats.round_nearest(x, fmt)  # noqa: E731
    if wd != 0.0:
        g = r(g + r(wd * w))
    m_new = r(r(mu * m) + g)
    u = r(lr * m_new)
    pre = w - u
    if rbits is not None:
        w_new = formats.round_stochastic(pre, fmt, rbits)
    else:
        w_new = r(pre)
    return w_new, m_new


def ref_sgd_kahan_update(w, m, c, g, lr, mu, wd, fmt: Format):
    """Algorithm 3: Kahan-compensated SGD update.  Returns (w', m', c')."""
    r = lambda x: formats.round_nearest(x, fmt)  # noqa: E731
    if wd != 0.0:
        g = r(g + r(wd * w))
    m_new = r(r(mu * m) + g)
    u = -r(lr * m_new)
    y = r(u - c)
    s = r(w + y)
    c_new = r(r(s - w) - y)
    return s, m_new, c_new


def ref_adamw_update(
    w, m, v, g, lr, b1, b2, eps, wd, denom1, denom2, fmt: Format, rbits=None
):
    """Algorithm 4 tensor ops (bias-correction scalars precomputed).

    Returns (w', m', v').
    """
    r = lambda x: formats.round_nearest(x, fmt)  # noqa: E731
    m_new = r(r(b1 * m) + r((1.0 - b1) * g))
    v_new = r(r(b2 * v) + r((1.0 - b2) * r(g * g)))
    mhat = r(m_new / denom1)
    vhat = r(jnp.sqrt(r(v_new / denom2)))
    t = r(mhat / r(vhat + eps))
    u = r(r(lr * t) + r(r(lr * wd) * w))
    pre = w - u
    if rbits is not None:
        w_new = formats.round_stochastic(pre, fmt, rbits)
    else:
        w_new = r(pre)
    return w_new, m_new, v_new
