"""L1 — Pallas kernels (interpret=True).

The paper's compute hot-spots written as explicit TPU-style kernels:

* ``qmatmul``  — tiled matmul with a VMEM accumulator tile: 16-bit-valued
  inputs, fp32 FMAC accumulation over K tiles, one output rounding on tile
  writeback.  This is the hardware-adaptation of the paper's 16-bit FMAC
  unit (DESIGN.md §3).
* ``optim_kernels`` — fused element-wise optimizer updates (SGD/AdamW ×
  nearest / stochastic-rounding / Kahan): the operation the paper's whole
  contribution concentrates on.
* ``ref`` — pure-jnp oracles; pytest asserts bit-identical results.

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin
cannot execute Mosaic custom-calls; real-TPU performance is *estimated*
from the BlockSpec VMEM footprint in DESIGN.md §Perf.
"""
