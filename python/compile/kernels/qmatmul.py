"""Quantised matmul Pallas kernel: the 16-bit FMAC unit as a TPU kernel.

Semantics (paper §2): inputs are 16-bit values, the multiply-accumulate
chain runs in a 32-bit accumulator, and exactly **one** nearest rounding is
applied to the operator output.  The kernel realises this with the canonical
TPU schedule:

  grid = (M/bm, N/bn, K/bk); the (i, j) output tile lives in VMEM as an
  fp32 accumulator across the K-tile loop (`o_ref` is revisited for each k
  because its index_map ignores the k axis), and the rounding happens once,
  on the final K tile — the "write back to 16-bit memory" step.

Block sizes default to MXU-friendly 128 and shrink to the actual dims for
the small models; shapes must divide the chosen blocks (aot-time shapes are
static, so this is checked eagerly).

A `jax.custom_vjp` gives the backward pass the same treatment: both
gradient matmuls are themselves quantised FMAC ops, matching `qops.qout`'s
rounded-cotangent rule on the jnp path bit for bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import formats
from ..formats import Format


def _pick_block(dim: int, preferred: int = 128) -> int:
    """Largest divisor of ``dim`` that is <= preferred (MXU tile target)."""
    if dim <= preferred:
        return dim
    for b in range(preferred, 0, -1):
        if dim % b == 0:
            return b
    return dim


def _mm_kernel(x_ref, y_ref, o_ref, *, nk: int, exp_bits: int, mant_bits: int):
    """One (i, j, k) grid step: accumulate a K tile into the output tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # fp32 FMAC accumulation (the wide accumulator of the 16-bit unit).
    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _writeback():
        # single rounding on operator output (nearest, RNE)
        fmt = Format("q", exp_bits, mant_bits)
        o_ref[...] = formats.round_nearest(o_ref[...], fmt)


def _qmatmul_raw(a: jnp.ndarray, b: jnp.ndarray, fmt: Format) -> jnp.ndarray:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul shape mismatch {a.shape} @ {b.shape}"
    bm, bn, bk = _pick_block(m), _pick_block(n), _pick_block(k)
    nk = k // bk
    kernel = functools.partial(
        _mm_kernel, nk=nk, exp_bits=fmt.exp_bits, mant_bits=fmt.mant_bits
    )
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _qmatmul(a, b, exp_bits: int, mant_bits: int):
    return _qmatmul_raw(a, b, Format("q", exp_bits, mant_bits))


def _fwd(a, b, exp_bits, mant_bits):
    return _qmatmul(a, b, exp_bits, mant_bits), (a, b)


def _bwd(exp_bits, mant_bits, res, g):
    a, b = res
    fmt = Format("q", exp_bits, mant_bits)
    # Both backward matmuls are 16-bit FMAC ops with rounded outputs, and the
    # incoming cotangent is rounded at this operator boundary (same rule as
    # qops._qcast_bwd).
    g = formats.round_nearest(g, fmt)
    da = _qmatmul_raw(g, b.T, fmt)
    db = _qmatmul_raw(a.T, g, fmt)
    return da, db


_qmatmul.defvjp(_fwd, _bwd)


def qmatmul_pallas(a: jnp.ndarray, b: jnp.ndarray, fmt: Format) -> jnp.ndarray:
    """Quantised 2-D matmul via the Pallas kernel (differentiable)."""
    return _qmatmul(a, b, fmt.exp_bits, fmt.mant_bits)


def vmem_bytes(m: int, n: int, k: int, preferred: int = 128) -> int:
    """Estimated VMEM footprint of one grid step (perf model, DESIGN.md §8).

    Three resident fp32 tiles: x (bm×bk), y (bk×bn), accumulator (bm×bn).
    """
    bm, bn, bk = (
        _pick_block(m, preferred),
        _pick_block(n, preferred),
        _pick_block(k, preferred),
    )
    return 4 * (bm * bk + bk * bn + bm * bn)
