"""Fused optimizer-update Pallas kernels (the paper's hot-spot, L1).

One grid step processes one 1-D tile of the flattened weight tensor; all
optimizer state for the tile stays resident in VMEM for the whole fused
chain (momentum update → update magnitude → weight-update rounding), so the
HBM traffic is exactly one read + one write per state tensor — the schedule
a BF16-only accelerator would use.

Three weight-update flavours, matching Algorithms 2-5:
  * nearest   — the standard (failing) mode.
  * stochastic— ⊖ with pre-drawn dither bits (hardware scheme of App. B.1).
  * kahan     — compensation buffer update fused in the same tile pass.

Bit-exact against ``ref.py`` (asserted by pytest across shapes/formats via
hypothesis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import formats
from ..formats import Format


def _pick_tile(n: int, preferred: int = 512) -> int:
    if n <= preferred:
        return n
    for t in range(preferred, 0, -1):
        if n % t == 0:
            return t
    return n


# --------------------------------------------------------------------------
# SGD kernels.
# --------------------------------------------------------------------------


def _sgd_nearest_kernel(
    w_ref, m_ref, g_ref, lr_ref, w_out, m_out, *, mu, wd, eb, mb
):
    fmt = Format("q", eb, mb)
    r = lambda x: formats.round_nearest(x, fmt)  # noqa: E731
    w, m, g, lr = w_ref[...], m_ref[...], g_ref[...], lr_ref[0]
    if wd != 0.0:
        g = r(g + r(wd * w))
    m_new = r(r(mu * m) + g)
    u = r(lr * m_new)
    w_out[...] = r(w - u)
    m_out[...] = m_new


def _sgd_stochastic_kernel(
    w_ref, m_ref, g_ref, rb_ref, lr_ref, w_out, m_out, *, mu, wd, eb, mb
):
    fmt = Format("q", eb, mb)
    r = lambda x: formats.round_nearest(x, fmt)  # noqa: E731
    w, m, g, lr = w_ref[...], m_ref[...], g_ref[...], lr_ref[0]
    if wd != 0.0:
        g = r(g + r(wd * w))
    m_new = r(r(mu * m) + g)
    u = r(lr * m_new)
    w_out[...] = formats.round_stochastic(w - u, fmt, rb_ref[...])
    m_out[...] = m_new


def _sgd_kahan_kernel(
    w_ref, m_ref, c_ref, g_ref, lr_ref, w_out, m_out, c_out, *, mu, wd, eb, mb
):
    fmt = Format("q", eb, mb)
    r = lambda x: formats.round_nearest(x, fmt)  # noqa: E731
    w, m, c, g, lr = (
        w_ref[...],
        m_ref[...],
        c_ref[...],
        g_ref[...],
        lr_ref[0],
    )
    if wd != 0.0:
        g = r(g + r(wd * w))
    m_new = r(r(mu * m) + g)
    u = -r(lr * m_new)
    y = r(u - c)
    s = r(w + y)
    c_out[...] = r(r(s - w) - y)
    w_out[...] = s
    m_out[...] = m_new


def _elemwise_call(kernel, n_in, n_out, n, args, tile=512):
    t = _pick_tile(n, tile)
    spec = pl.BlockSpec((t,), lambda i: (i,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    in_specs = [spec] * (n_in - 1) + [scalar_spec]  # last input is lr
    return pl.pallas_call(
        kernel,
        grid=(n // t,),
        in_specs=in_specs,
        out_specs=[spec] * n_out,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * n_out,
        interpret=True,
    )(*args)


def sgd_update_pallas(w, m, g, lr, mu, wd, fmt: Format, rbits=None):
    """Fused Algorithm-2 step (nearest or stochastic ⊖).  Flat tensors."""
    (n,) = w.shape
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1)
    if rbits is None:
        kern = functools.partial(
            _sgd_nearest_kernel,
            mu=mu,
            wd=wd,
            eb=fmt.exp_bits,
            mb=fmt.mant_bits,
        )
        w2, m2 = _elemwise_call(kern, 4, 2, n, (w, m, g, lr_arr))
    else:
        kern = functools.partial(
            _sgd_stochastic_kernel,
            mu=mu,
            wd=wd,
            eb=fmt.exp_bits,
            mb=fmt.mant_bits,
        )
        w2, m2 = _elemwise_call(kern, 5, 2, n, (w, m, g, rbits, lr_arr))
    return w2, m2


def sgd_kahan_update_pallas(w, m, c, g, lr, mu, wd, fmt: Format):
    """Fused Algorithm-3 step.  Returns (w', m', c')."""
    (n,) = w.shape
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1)
    kern = functools.partial(
        _sgd_kahan_kernel, mu=mu, wd=wd, eb=fmt.exp_bits, mb=fmt.mant_bits
    )
    return _elemwise_call(kern, 5, 3, n, (w, m, c, g, lr_arr))


# --------------------------------------------------------------------------
# AdamW kernels.
# --------------------------------------------------------------------------


def _adamw_kernel(
    w_ref,
    m_ref,
    v_ref,
    g_ref,
    scal_ref,
    w_out,
    m_out,
    v_out,
    *,
    b1,
    b2,
    eps,
    wd,
    eb,
    mb,
):
    fmt = Format("q", eb, mb)
    r = lambda x: formats.round_nearest(x, fmt)  # noqa: E731
    w, m, v, g = w_ref[...], m_ref[...], v_ref[...], g_ref[...]
    lr, denom1, denom2 = scal_ref[0], scal_ref[1], scal_ref[2]
    m_new = r(r(b1 * m) + r((1.0 - b1) * g))
    v_new = r(r(b2 * v) + r((1.0 - b2) * r(g * g)))
    mhat = r(m_new / denom1)
    vhat = r(jnp.sqrt(r(v_new / denom2)))
    t = r(mhat / r(vhat + eps))
    u = r(r(lr * t) + r(r(lr * wd) * w))
    w_out[...] = r(w - u)
    m_out[...] = m_new
    v_out[...] = v_new


def _adamw_sr_kernel(
    w_ref,
    m_ref,
    v_ref,
    g_ref,
    rb_ref,
    scal_ref,
    w_out,
    m_out,
    v_out,
    *,
    b1,
    b2,
    eps,
    wd,
    eb,
    mb,
):
    fmt = Format("q", eb, mb)
    r = lambda x: formats.round_nearest(x, fmt)  # noqa: E731
    w, m, v, g = w_ref[...], m_ref[...], v_ref[...], g_ref[...]
    lr, denom1, denom2 = scal_ref[0], scal_ref[1], scal_ref[2]
    m_new = r(r(b1 * m) + r((1.0 - b1) * g))
    v_new = r(r(b2 * v) + r((1.0 - b2) * r(g * g)))
    mhat = r(m_new / denom1)
    vhat = r(jnp.sqrt(r(v_new / denom2)))
    t = r(mhat / r(vhat + eps))
    u = r(r(lr * t) + r(r(lr * wd) * w))
    w_out[...] = formats.round_stochastic(w - u, fmt, rb_ref[...])
    m_out[...] = m_new
    v_out[...] = v_new


def adamw_update_pallas(
    w, m, v, g, lr, b1, b2, eps, wd, denom1, denom2, fmt: Format, rbits=None
):
    """Fused Algorithm-4 tensor ops.  Returns (w', m', v')."""
    (n,) = w.shape
    scal = jnp.stack(
        [
            jnp.asarray(lr, jnp.float32),
            jnp.asarray(denom1, jnp.float32),
            jnp.asarray(denom2, jnp.float32),
        ]
    )
    t = _pick_tile(n)
    spec = pl.BlockSpec((t,), lambda i: (i,))
    scal_spec = pl.BlockSpec((3,), lambda i: (0,))
    if rbits is None:
        kern = functools.partial(
            _adamw_kernel,
            b1=b1,
            b2=b2,
            eps=eps,
            wd=wd,
            eb=fmt.exp_bits,
            mb=fmt.mant_bits,
        )
        ins = (w, m, v, g, scal)
        in_specs = [spec] * 4 + [scal_spec]
    else:
        kern = functools.partial(
            _adamw_sr_kernel,
            b1=b1,
            b2=b2,
            eps=eps,
            wd=wd,
            eb=fmt.exp_bits,
            mb=fmt.mant_bits,
        )
        ins = (w, m, v, g, rbits, scal)
        in_specs = [spec] * 5 + [scal_spec]
    return pl.pallas_call(
        kern,
        grid=(n // t,),
        in_specs=in_specs,
        out_specs=[spec] * 3,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=True,
    )(*ins)
