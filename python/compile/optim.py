"""Optimizers with precision-mode-aware weight updates (L2).

Implements the paper's Algorithms 2-5 (SGD / AdamW × stochastic-rounding /
Kahan-summation) plus the baselines used across the evaluation:

  fp32        — exact 32-bit training (paper's baseline column).
  standard16  — every optimizer op consumes in-format values and nearest-
                rounds its output; the weight-update subtraction is nearest-
                rounded (the *failing* standard algorithm, Table 3/4 rightmost).
  mixed16     — the Table 3 ablation: fwd/bwd compute is 16-bit, but weights
                and optimizer state are fp32 with an *exact* update (this is
                what closes the gap and isolates the bottleneck).
  sr16        — Algorithm 2/4: the weight-update subtraction output is
                stochastically rounded; everything else nearest (⊖ operator).
  kahan16     — Algorithm 3/5: nearest rounding everywhere, but the update is
                accumulated through a 16-bit Kahan compensation buffer.
  srkahan16   — both techniques simultaneously (Figure 11).

Every tensor of optimizer state (momentum, second moment, Kahan buffer, bias
correction scalars) lives in the emulated 16-bit format in the *16 modes —
the whole point of the paper is that no fp32 storage or FPU is needed.

The per-mode cancellation fraction (share of weight coordinates whose
non-zero update was cancelled by rounding — Figure 9's metric) is returned
as an auxiliary output of ``update`` so the rust coordinator can log it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import formats
from .formats import Format

Params = Dict[str, jnp.ndarray]
State = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class PrecisionMode:
    """Full precision policy for one training run."""

    name: str  # fp32 | standard16 | mixed16 | sr16 | kahan16 | srkahan16
    fmt: Format = formats.BF16

    @property
    def is_fp32(self) -> bool:
        return self.name == "fp32"

    @property
    def fp32_weights(self) -> bool:
        return self.name in ("fp32", "mixed16")

    @property
    def exact_update(self) -> bool:
        return self.name in ("fp32", "mixed16")

    @property
    def stochastic(self) -> bool:
        return self.name in ("sr16", "srkahan16")

    @property
    def kahan(self) -> bool:
        return self.name in ("kahan16", "srkahan16")

    @property
    def compute_fmt(self) -> Format:
        """Format for forward/backward activations+gradients."""
        return formats.FP32 if self.name == "fp32" else self.fmt


MODE_NAMES = ("fp32", "standard16", "mixed16", "sr16", "kahan16", "srkahan16")


def make_mode(name: str, fmt_name: str = "bf16") -> PrecisionMode:
    if name not in MODE_NAMES:
        raise ValueError(f"unknown precision mode {name!r}")
    return PrecisionMode(name, formats.FORMATS[fmt_name])


# --------------------------------------------------------------------------
# Rounding helpers bound to a mode.
# --------------------------------------------------------------------------


def _rn(mode: PrecisionMode):
    """Nearest-rounding for optimizer-internal ops under ``mode``."""
    if mode.exact_update:
        return lambda x: x
    return lambda x: formats.round_nearest(x, mode.fmt)


def _weight_round(mode: PrecisionMode, x, key):
    """Round the weight-update subtraction output per the mode's policy."""
    if mode.exact_update:
        return x
    if mode.stochastic:
        rbits = formats.random_bits_like(key, x)
        return formats.round_stochastic(x, mode.fmt, rbits)
    return formats.round_nearest(x, mode.fmt)


def _kahan_step(r, w, u, c, mode=None, key=None):
    """Algorithm 1 / lines 7-10 of Algorithms 3&5.

    u is the (negative) model update; c the compensation buffer.  All four
    ops nearest-round their outputs — only 16-bit FPUs required.  In the
    combined srkahan16 mode (Figure 11) the weight-accumulate output
    ``s = w + y`` is stochastically rounded instead, so both techniques act
    on the same update.
    """
    y = r(u - c)
    if mode is not None and mode.stochastic:
        rbits = formats.random_bits_like(key, w)
        s = formats.round_stochastic(w + y, mode.fmt, rbits)
    else:
        s = r(w + y)
    c_new = r(r(s - w) - y)
    return s, c_new


def _cancel_frac(w_old, w_new, update):
    """Fraction of coordinates with non-zero update cancelled by rounding."""
    nz = update != 0.0
    cancelled = jnp.logical_and(nz, w_new == w_old)
    return jnp.sum(cancelled).astype(jnp.float32), jnp.sum(nz).astype(
        jnp.float32
    )


# --------------------------------------------------------------------------
# SGD with momentum (Algorithms 2 & 3).
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SgdConfig:
    momentum: float = 0.9
    weight_decay: float = 0.0


def sgd_init(params: Params, mode: PrecisionMode, cfg: SgdConfig) -> State:
    state: State = {}
    if cfg.momentum != 0.0:
        for k, v in params.items():
            state[f"m.{k}"] = jnp.zeros_like(v)
    if mode.kahan:
        for k, v in params.items():
            state[f"c.{k}"] = jnp.zeros_like(v)
    return state


def sgd_update(
    params: Params,
    state: State,
    grads: Params,
    lr: jnp.ndarray,
    key: jax.Array,
    mode: PrecisionMode,
    cfg: SgdConfig,
) -> Tuple[Params, State, jnp.ndarray]:
    """One SGD step.  Returns (params', state', cancel_fraction)."""
    r = _rn(mode)
    new_p: Params = {}
    new_s: State = {}
    cancelled = jnp.zeros((), jnp.float32)
    total = jnp.zeros((), jnp.float32)
    keys = jax.random.split(key, len(params))
    for (k, w), kk in zip(sorted(params.items()), keys):
        g = grads[k]
        if cfg.weight_decay != 0.0:
            g = r(g + r(cfg.weight_decay * w))
        if cfg.momentum != 0.0:
            m = r(r(cfg.momentum * state[f"m.{k}"]) + g)
            new_s[f"m.{k}"] = m
        else:
            m = g
        u = r(lr * m)  # the model update magnitude
        if mode.kahan:
            w_new, c_new = _kahan_step(
                r, w, -u, state[f"c.{k}"], mode=mode, key=kk
            )
            new_s[f"c.{k}"] = c_new
        else:
            w_new = _weight_round(mode, w - u, kk)
        c, t = _cancel_frac(w, w_new, u)
        cancelled += c
        total += t
        new_p[k] = w_new
    frac = cancelled / jnp.maximum(total, 1.0)
    return new_p, new_s, frac


# --------------------------------------------------------------------------
# AdamW (Algorithms 4 & 5).
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    beta1: float = 0.9
    # The paper uses beta2 = 0.997 for the 16-bit modes because 0.999 rounds
    # to 1.0 in bf16 (Appendix C.1).  Callers pick the value per mode via
    # ``beta2_for_mode``.
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01

    def beta2_for_mode(self, mode: PrecisionMode) -> float:
        if mode.is_fp32 or mode.name == "mixed16":
            return self.beta2
        # largest representable value < beta2 in the mode's format
        b = formats.round_nearest_py(self.beta2, mode.fmt)
        if b >= 1.0:
            # e.g. 0.999 rounds to 1.0 in bf16 → back off to the largest
            # representable value below 1.0 (0.99609375 for bf16 — the
            # paper's "0.997", Appendix C.1).
            b = 1.0 - 2.0 ** -(mode.fmt.mant_bits + 1)
        return b


def adamw_init(params: Params, mode: PrecisionMode, cfg: AdamWConfig) -> State:
    state: State = {}
    for k, v in params.items():
        state[f"m.{k}"] = jnp.zeros_like(v)
        state[f"v.{k}"] = jnp.zeros_like(v)
    if mode.kahan:
        for k, v in params.items():
            state[f"c.{k}"] = jnp.zeros_like(v)
    # bias-correction product accumulators (Algorithm 4 lines 7-8), stored
    # in-format like everything else.
    state["bc1"] = jnp.ones((), jnp.float32)
    state["bc2"] = jnp.ones((), jnp.float32)
    return state


def adamw_update(
    params: Params,
    state: State,
    grads: Params,
    lr: jnp.ndarray,
    key: jax.Array,
    mode: PrecisionMode,
    cfg: AdamWConfig,
) -> Tuple[Params, State, jnp.ndarray]:
    r = _rn(mode)
    b1 = cfg.beta1
    b2 = cfg.beta2_for_mode(mode)
    new_p: Params = {}
    new_s: State = {}
    bc1 = r(state["bc1"] * b1)
    bc2 = r(state["bc2"] * b2)
    new_s["bc1"] = bc1
    new_s["bc2"] = bc2
    denom1 = r(1.0 - bc1)
    denom2 = r(1.0 - bc2)
    cancelled = jnp.zeros((), jnp.float32)
    total = jnp.zeros((), jnp.float32)
    keys = jax.random.split(key, len(params))
    for (k, w), kk in zip(sorted(params.items()), keys):
        g = grads[k]
        m = r(r(b1 * state[f"m.{k}"]) + r((1.0 - b1) * g))
        v = r(r(b2 * state[f"v.{k}"]) + r((1.0 - b2) * r(g * g)))
        new_s[f"m.{k}"] = m
        new_s[f"v.{k}"] = v
        mhat = r(m / denom1)
        vhat = r(jnp.sqrt(r(v / denom2)))
        t = r(mhat / r(vhat + cfg.eps))
        u = r(r(lr * t) + r(r(lr * cfg.weight_decay) * w))
        if mode.kahan:
            w_new, c_new = _kahan_step(
                r, w, -u, state[f"c.{k}"], mode=mode, key=kk
            )
            new_s[f"c.{k}"] = c_new
        else:
            w_new = _weight_round(mode, w - u, kk)
        c, t2 = _cancel_frac(w, w_new, u)
        cancelled += c
        total += t2
        new_p[k] = w_new
    frac = cancelled / jnp.maximum(total, 1.0)
    return new_p, new_s, frac


# --------------------------------------------------------------------------
# Uniform facade used by train_step.py.
# --------------------------------------------------------------------------


OPTIMIZERS = ("sgd", "adamw")


def opt_init(name, params, mode, cfg) -> State:
    if name == "sgd":
        return sgd_init(params, mode, cfg)
    if name == "adamw":
        return adamw_init(params, mode, cfg)
    raise ValueError(f"unknown optimizer {name!r}")


def opt_update(name, params, state, grads, lr, key, mode, cfg):
    if name == "sgd":
        return sgd_update(params, state, grads, lr, key, mode, cfg)
    if name == "adamw":
        return adamw_update(params, state, grads, lr, key, mode, cfg)
    raise ValueError(f"unknown optimizer {name!r}")
