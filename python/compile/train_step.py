"""Assemble full training/eval step functions for AOT lowering (L2).

A *step artifact* is one jitted function per (application × precision mode):

  train:  step(params…, opt_state…, x, y, seed, lr)
              -> (params'…, opt_state'…, loss, metric, cancel_frac)
  eval:   eval(params…, x, y) -> (loss, metric, preds)
  init:   init(seed) -> (params…,)

All tensors cross the boundary as f32/i32 (emulated formats are value
subsets of f32 — see formats.py).  The argument order is deterministic:
sorted parameter keys, then sorted optimizer-state keys, then batch inputs,
then scalars; ``signature()`` reports it for the manifest so the rust
runtime can bind buffers without ever importing python.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import formats, optim, qops
from .models import Model


def _sorted_keys(d: Dict[str, jnp.ndarray]) -> List[str]:
    return sorted(d.keys())


class StepBuilder:
    """Builds the three artifact functions for one application × mode."""

    def __init__(
        self,
        model: Model,
        mode: optim.PrecisionMode,
        opt_name: str,
        opt_cfg,
        use_pallas: bool = False,
    ):
        self.model = model
        self.mode = mode
        self.opt_name = opt_name
        self.opt_cfg = opt_cfg
        self.qcfg = qops.QConfig(mode.compute_fmt, use_pallas=use_pallas)
        # The RNG seed input exists only when the update actually consumes
        # random bits; otherwise jax prunes the unused argument during
        # lowering and the executable's signature would not match the
        # manifest (aot.py asserts the final parameter count).
        self.uses_seed = mode.stochastic
        # Probe shapes once with concrete zeros to fix the state layout.
        probe = model.init(jax.random.PRNGKey(0))
        self.param_keys = _sorted_keys(probe)
        self.param_shapes = {k: tuple(probe[k].shape) for k in self.param_keys}
        state = optim.opt_init(opt_name, probe, mode, opt_cfg)
        self.state_keys = _sorted_keys(state)
        self.state_shapes = {k: tuple(state[k].shape) for k in self.state_keys}

    # -- pytree <-> flat helpers ------------------------------------------

    def _pack(self, params, state):
        return [params[k] for k in self.param_keys] + [
            state[k] for k in self.state_keys
        ]

    def _unpack(self, flat):
        np_ = len(self.param_keys)
        params = dict(zip(self.param_keys, flat[:np_]))
        state = dict(zip(self.state_keys, flat[np_:]))
        return params, state

    # -- artifact functions ------------------------------------------------

    def init_fn(self):
        """init(seed:i32) -> (params…, opt_state…) with in-format weights."""

        def f(seed):
            key = jax.random.PRNGKey(seed)
            params = self.model.init(key)
            if not self.mode.fp32_weights:
                params = {
                    k: formats.round_nearest(v, self.mode.fmt)
                    for k, v in params.items()
                }
            state = optim.opt_init(
                self.opt_name, params, self.mode, self.opt_cfg
            )
            return tuple(self._pack(params, state))

        return f

    def train_fn(self):
        model, mode, qcfg = self.model, self.mode, self.qcfg

        def f(*args):
            n = len(self.param_keys) + len(self.state_keys)
            if self.uses_seed:
                flat, (x, y, seed, lr) = list(args[:n]), args[n:]
            else:
                flat, (x, y, lr) = list(args[:n]), args[n:]
                seed = 0
            params, state = self._unpack(flat)
            key = jax.random.PRNGKey(seed)

            def loss_fn(p):
                loss, metric = model.loss_and_metric(p, x, y, qcfg)
                return loss, metric

            (loss, metric), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params)
            new_p, new_s, cancel = optim.opt_update(
                self.opt_name,
                params,
                state,
                grads,
                lr,
                key,
                mode,
                self.opt_cfg,
            )
            out = self._pack(new_p, new_s)
            return tuple(out) + (loss, metric, cancel)

        return f

    def eval_fn(self):
        model, qcfg = self.model, self.qcfg

        def f(*args):
            n = len(self.param_keys)
            params = dict(zip(self.param_keys, args[:n]))
            x, y = args[n], args[n + 1]
            loss, metric = model.loss_and_metric(params, x, y, qcfg)
            preds = model.predict(params, x, qcfg).astype(jnp.float32)
            return loss, metric, preds

        return f

    # -- manifest metadata ---------------------------------------------------

    def _spec(self, shape, dtype="f32", role="param", key=""):
        return {
            "role": role,
            "key": key,
            "shape": list(shape),
            "dtype": dtype,
        }

    def signature(self) -> Tuple[list, list, list]:
        """(train_inputs, train_outputs, eval_inputs) manifest entries."""
        ins = [
            self._spec(self.param_shapes[k], role="param", key=k)
            for k in self.param_keys
        ]
        ins += [
            self._spec(self.state_shapes[k], role="opt_state", key=k)
            for k in self.state_keys
        ]
        xs, xd = self.model.x_spec
        ys, yd = self.model.y_spec
        ins.append(self._spec(xs, xd, role="x"))
        ins.append(self._spec(ys, yd, role="y"))
        if self.uses_seed:
            ins.append(self._spec((), "i32", role="seed"))
        ins.append(self._spec((), "f32", role="lr"))
        outs = [
            self._spec(self.param_shapes[k], role="param", key=k)
            for k in self.param_keys
        ]
        outs += [
            self._spec(self.state_shapes[k], role="opt_state", key=k)
            for k in self.state_keys
        ]
        outs.append(self._spec((), "f32", role="loss"))
        outs.append(self._spec((), "f32", role="metric"))
        outs.append(self._spec((), "f32", role="cancel_frac"))
        eval_ins = [
            self._spec(self.param_shapes[k], role="param", key=k)
            for k in self.param_keys
        ]
        eval_ins.append(self._spec(xs, xd, role="x"))
        eval_ins.append(self._spec(ys, yd, role="y"))
        return ins, outs, eval_ins

    def example_args(self):
        """ShapeDtypeStructs for jax.jit(...).lower of the train step."""
        structs = []
        for k in self.param_keys:
            structs.append(
                jax.ShapeDtypeStruct(self.param_shapes[k], jnp.float32)
            )
        for k in self.state_keys:
            structs.append(
                jax.ShapeDtypeStruct(self.state_shapes[k], jnp.float32)
            )
        xs, xd = self.model.x_spec
        ys, yd = self.model.y_spec
        jdt = {"f32": jnp.float32, "i32": jnp.int32}
        structs.append(jax.ShapeDtypeStruct(xs, jdt[xd]))
        structs.append(jax.ShapeDtypeStruct(ys, jdt[yd]))
        if self.uses_seed:
            structs.append(jax.ShapeDtypeStruct((), jnp.int32))  # seed
        structs.append(jax.ShapeDtypeStruct((), jnp.float32))  # lr
        return structs

    def eval_example_args(self):
        structs = [
            jax.ShapeDtypeStruct(self.param_shapes[k], jnp.float32)
            for k in self.param_keys
        ]
        xs, xd = self.model.x_spec
        ys, yd = self.model.y_spec
        jdt = {"f32": jnp.float32, "i32": jnp.int32}
        structs.append(jax.ShapeDtypeStruct(xs, jdt[xd]))
        structs.append(jax.ShapeDtypeStruct(ys, jdt[yd]))
        return structs
