"""Transformer encoder family (BERT-MNLI / BERT-Wiki103 / GPT stand-ins).

Pre-norm transformer with learned positional embeddings.  Two task heads:

  * ``classification`` — mean-pool + linear head, 3-way entailment labels
    (the BERT-MNLI stand-in; Figure 1 / Table 3).
  * ``lm``             — causal language modelling with weight-tied output
    projection (the BERT-Wiki103 / end-to-end-GPT stand-in; PPL metric).

Attention, projections, MLP, layernorm and residual adds all route through
the quantised operator set.  AdamW with the paper's β₂ handling (Appendix
C.1) is applied by ``optim.py``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .. import qops
from . import Model


def _dense_init(key, a, b):
    scale = 1.0 / math.sqrt(a)
    return jax.random.uniform(key, (a, b), jnp.float32, -scale, scale)


def make(hp: dict) -> Model:
    task = hp.get("task", "classification")
    vocab = int(hp.get("vocab", 512))
    dim = int(hp.get("dim", 64))
    heads = int(hp.get("heads", 4))
    layers = int(hp.get("layers", 2))
    seq = int(hp.get("seq", 32))
    num_classes = int(hp.get("num_classes", 3))
    batch = int(hp.get("batch", 32))
    hdim = dim // heads
    assert hdim * heads == dim, "dim must divide heads"

    def init(key):
        params = {}
        key, k1, k2 = jax.random.split(key, 3)
        params["tok.emb"] = (
            jax.random.normal(k1, (vocab, dim), jnp.float32) * 0.02
        )
        params["pos.emb"] = (
            jax.random.normal(k2, (seq, dim), jnp.float32) * 0.02
        )
        for l in range(layers):
            for name, (a, b) in {
                "q": (dim, dim),
                "k": (dim, dim),
                "v": (dim, dim),
                "o": (dim, dim),
                "fc1": (dim, 4 * dim),
                "fc2": (4 * dim, dim),
            }.items():
                key, kk = jax.random.split(key)
                params[f"l{l}.{name}.w"] = _dense_init(kk, a, b)
                params[f"l{l}.{name}.b"] = jnp.zeros((b,), jnp.float32)
            params[f"l{l}.ln1.g"] = jnp.ones((dim,), jnp.float32)
            params[f"l{l}.ln1.b"] = jnp.zeros((dim,), jnp.float32)
            params[f"l{l}.ln2.g"] = jnp.ones((dim,), jnp.float32)
            params[f"l{l}.ln2.b"] = jnp.zeros((dim,), jnp.float32)
        params["lnf.g"] = jnp.ones((dim,), jnp.float32)
        params["lnf.b"] = jnp.zeros((dim,), jnp.float32)
        if task == "classification":
            key, kk = jax.random.split(key)
            params["head.w"] = _dense_init(kk, dim, num_classes)
            params["head.b"] = jnp.zeros((num_classes,), jnp.float32)
        return params

    def _proj(h, params, l, name, qcfg):
        """(B,S,D) @ (D,E) + b — flattened to a 2-D FMAC matmul."""
        b, s, d = h.shape
        w = params[f"l{l}.{name}.w"]
        bias = params[f"l{l}.{name}.b"]
        flat = h.reshape(b * s, d)
        out = qops.qlinear(flat, w, bias, qcfg)
        return out.reshape(b, s, -1)

    def _attention(h, params, l, qcfg, causal):
        b, s, d = h.shape
        q = _proj(h, params, l, "q", qcfg).reshape(b, s, heads, hdim)
        k = _proj(h, params, l, "k", qcfg).reshape(b, s, heads, hdim)
        v = _proj(h, params, l, "v", qcfg).reshape(b, s, heads, hdim)
        # scores: (B,H,S,S), FMAC matmul + rounded output
        scores = qops.qout(
            jnp.einsum("bshd,bthd->bhst", q, k) / math.sqrt(hdim), qcfg
        )
        if causal:
            mask = jnp.tril(jnp.ones((s, s), jnp.float32))
            scores = jnp.where(mask[None, None] > 0, scores, -1e9)
        attn = qops.qsoftmax(scores, qcfg, axis=-1)
        ctx = qops.qout(jnp.einsum("bhst,bthd->bshd", attn, v), qcfg)
        ctx = ctx.reshape(b, s, d)
        return _proj(ctx, params, l, "o", qcfg)

    def trunk(params, tokens, qcfg, causal):
        h = qops.qembed(params["tok.emb"], tokens, qcfg)
        h = qops.qadd(h, qops.qparam(params["pos.emb"], qcfg)[None], qcfg)
        for l in range(layers):
            n = qops.qlayernorm(
                h, params[f"l{l}.ln1.g"], params[f"l{l}.ln1.b"], qcfg
            )
            h = qops.qadd(h, _attention(n, params, l, qcfg, causal), qcfg)
            n = qops.qlayernorm(
                h, params[f"l{l}.ln2.g"], params[f"l{l}.ln2.b"], qcfg
            )
            m = _proj(n, params, l, "fc1", qcfg)
            m = qops.qgelu(m, qcfg)
            b_, s_, _ = m.shape
            w2 = params[f"l{l}.fc2.w"]
            m = qops.qlinear(
                m.reshape(b_ * s_, -1), w2, params[f"l{l}.fc2.b"], qcfg
            ).reshape(b_, s_, dim)
            h = qops.qadd(h, m, qcfg)
        return qops.qlayernorm(h, params["lnf.g"], params["lnf.b"], qcfg)

    if task == "classification":

        def loss_and_metric(params, x, y, qcfg):
            h = trunk(params, x, qcfg, causal=False)
            pooled = qops.qmean(h, qcfg, axis=1)
            logits = qops.qlinear(
                pooled, params["head.w"], params["head.b"], qcfg
            )
            loss = qops.softmax_xent(logits, y, qcfg)
            acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
            return loss, acc

        def predict(params, x, qcfg):
            h = trunk(params, x, qcfg, causal=False)
            pooled = qops.qmean(h, qcfg, axis=1)
            logits = qops.qlinear(
                pooled, params["head.w"], params["head.b"], qcfg
            )
            return jnp.argmax(logits, -1)

        y_spec = ((batch,), "i32")
        metric_name = "accuracy"
    else:  # causal LM

        def loss_and_metric(params, x, y, qcfg):
            h = trunk(params, x, qcfg, causal=True)
            b, s, d = h.shape
            emb = qops.qparam(params["tok.emb"], qcfg)
            logits = qops.qout(
                jnp.matmul(h.reshape(b * s, d), emb.T), qcfg
            ).reshape(b, s, vocab)
            loss = qops.softmax_xent(logits, y, qcfg)
            acc = jnp.mean(
                (jnp.argmax(logits, -1) == y).astype(jnp.float32)
            )
            return loss, acc

        def predict(params, x, qcfg):
            h = trunk(params, x, qcfg, causal=True)
            b, s, d = h.shape
            emb = qops.qparam(params["tok.emb"], qcfg)
            logits = jnp.matmul(h.reshape(b * s, d), emb.T).reshape(
                b, s, vocab
            )
            # next-token prediction at the last position
            return jnp.argmax(logits[:, -1, :], -1)

        y_spec = ((batch, seq), "i32")
        metric_name = "ppl"  # rust reports exp(loss)

    return Model(
        name=f"transformer-{task}",
        init=init,
        loss_and_metric=loss_and_metric,
        predict=predict,
        x_spec=((batch, seq), "i32"),
        y_spec=y_spec,
        metric_name=metric_name,
    )
