"""Model zoo (L2).

Each model module exposes ``make(hparams: dict) -> Model``.  ``Model`` is a
uniform facade consumed by ``train_step.py``/``aot.py``:

  * ``init(key) -> params``                 (dict[str, f32 array])
  * ``loss_and_metric(params, x, y, qcfg)`` -> (scalar loss, scalar metric)
  * ``predict(params, x, qcfg)``            -> per-example outputs for eval
  * ``x_spec`` / ``y_spec``                 (shape, dtype) of one batch

Parameters are plain flat dicts so the AOT manifest can record a stable,
sorted ordering that the rust runtime reproduces exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]
Spec = Tuple[Tuple[int, ...], str]  # (shape, dtype-name)


@dataclasses.dataclass(frozen=True)
class Model:
    name: str
    init: Callable
    loss_and_metric: Callable  # (params, x, y, qcfg) -> (loss, metric)
    predict: Callable  # (params, x, qcfg) -> outputs
    x_spec: Spec
    y_spec: Spec
    metric_name: str = "accuracy"


def get(family: str, hparams: dict) -> Model:
    from . import cnn, dlrm, lstm, mlp, transformer

    registry = {
        "mlp": mlp.make,
        "cnn": cnn.make,
        "transformer": transformer.make,
        "dlrm": dlrm.make,
        "lstm": lstm.make,
    }
    if family not in registry:
        raise ValueError(f"unknown model family {family!r}")
    return registry[family](hparams)
