"""Small residual CNN family (ResNet-18/CIFAR10 and ResNet-50/ImageNet
stand-ins; DESIGN.md §4).

VGG-style stem + residual blocks with stride-2 downsampling between stages,
global average pool, linear classifier.  Every conv/linear/add routes through
the quantised operator set, so the 16-bit FMAC semantics cover the full
forward and backward graphs.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .. import qops
from . import Model


def _conv_init(key, out_c, in_c, k):
    scale = math.sqrt(2.0 / (in_c * k * k))
    return jax.random.normal(key, (out_c, in_c, k, k), jnp.float32) * scale


def make(hp: dict) -> Model:
    channels = list(hp.get("channels", [16, 32, 64]))
    blocks = int(hp.get("blocks", 1))  # residual blocks per stage
    num_classes = int(hp.get("num_classes", 10))
    batch = int(hp.get("batch", 32))
    image = int(hp.get("image", 32))

    def init(key):
        params = {}
        key, k = jax.random.split(key)
        params["stem.w"] = _conv_init(k, channels[0], 3, 3)
        in_c = channels[0]
        for s, c in enumerate(channels):
            for b in range(blocks):
                key, k1, k2 = jax.random.split(key, 3)
                params[f"s{s}b{b}.c1.w"] = _conv_init(k1, c, in_c, 3)
                params[f"s{s}b{b}.c2.w"] = _conv_init(k2, c, c, 3)
                if in_c != c:
                    key, k3 = jax.random.split(key)
                    params[f"s{s}b{b}.proj.w"] = _conv_init(k3, c, in_c, 1)
                in_c = c
        key, k = jax.random.split(key)
        scale = 1.0 / math.sqrt(in_c)
        params["head.w"] = jax.random.uniform(
            k, (in_c, num_classes), jnp.float32, -scale, scale
        )
        params["head.b"] = jnp.zeros((num_classes,), jnp.float32)
        return params

    def forward(params, x, qcfg):
        h = qops.qdata(x, qcfg)
        h = qops.qconv2d(h, params["stem.w"], qcfg)
        h = qops.qrelu(h, qcfg)
        for s, c in enumerate(channels):
            for b in range(blocks):
                stride = 2 if (b == 0 and s > 0) else 1
                r = h
                h = qops.qconv2d(h, params[f"s{s}b{b}.c1.w"], qcfg, stride=stride)
                h = qops.qrelu(h, qcfg)
                h = qops.qconv2d(h, params[f"s{s}b{b}.c2.w"], qcfg)
                if f"s{s}b{b}.proj.w" in params:
                    r = qops.qconv2d(
                        r, params[f"s{s}b{b}.proj.w"], qcfg, stride=stride
                    )
                elif stride != 1:
                    r = r[:, :, ::stride, ::stride]
                h = qops.qrelu(qops.qadd(h, r, qcfg), qcfg)
        h = qops.qmean(h, qcfg, axis=(2, 3))  # global average pool
        return qops.qlinear(h, params["head.w"], params["head.b"], qcfg)

    def loss_and_metric(params, x, y, qcfg):
        logits = forward(params, x, qcfg)
        loss = qops.softmax_xent(logits, y, qcfg)
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return loss, acc

    def predict(params, x, qcfg):
        return jnp.argmax(forward(params, x, qcfg), -1)

    return Model(
        name="cnn",
        init=init,
        loss_and_metric=loss_and_metric,
        predict=predict,
        x_spec=((batch, 3, image, image), "f32"),
        y_spec=((batch,), "i32"),
        metric_name="accuracy",
    )
