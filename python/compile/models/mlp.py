"""MLP model family: least-squares regression and small classifiers.

``task="regression"`` with ``hidden=[]`` is exactly the paper's Section 3.1
theory-validation model (Figure 2): linear least squares, loss
0.5/n Σ ||x_i^T w - y_i||², trained with per-operator rounding.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .. import qops
from . import Model


def make(hp: dict) -> Model:
    in_dim = int(hp.get("in_dim", 10))
    hidden = list(hp.get("hidden", []))
    task = hp.get("task", "regression")
    num_classes = int(hp.get("num_classes", 10))
    batch = int(hp.get("batch", 32))
    out_dim = 1 if task == "regression" else num_classes
    dims = [in_dim] + hidden + [out_dim]

    def init(key):
        params = {}
        for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
            key, k1 = jax.random.split(key)
            scale = 1.0 / math.sqrt(a)
            params[f"l{i}.w"] = (
                jax.random.uniform(k1, (a, b), jnp.float32, -scale, scale)
            )
            params[f"l{i}.b"] = jnp.zeros((b,), jnp.float32)
        return params

    def forward(params, x, qcfg):
        h = qops.qdata(x, qcfg)
        n = len(dims) - 1
        for i in range(n):
            h = qops.qlinear(h, params[f"l{i}.w"], params[f"l{i}.b"], qcfg)
            if i + 1 < n:
                h = qops.qrelu(h, qcfg)
        return h

    def loss_and_metric(params, x, y, qcfg):
        out = forward(params, x, qcfg)
        if task == "regression":
            pred = out[:, 0]
            loss = qops.mse_loss(pred, y, qcfg)
            return loss, loss  # metric = training loss for the theory exp
        loss = qops.softmax_xent(out, y, qcfg)
        acc = jnp.mean((jnp.argmax(out, axis=-1) == y).astype(jnp.float32))
        return loss, acc

    def predict(params, x, qcfg):
        out = forward(params, x, qcfg)
        return out[:, 0] if task == "regression" else jnp.argmax(out, -1)

    y_dtype = "f32" if task == "regression" else "i32"
    return Model(
        name=f"mlp-{task}",
        init=init,
        loss_and_metric=loss_and_metric,
        predict=predict,
        x_spec=((batch, in_dim), "f32"),
        y_spec=((batch,), y_dtype),
        metric_name="loss" if task == "regression" else "accuracy",
    )
