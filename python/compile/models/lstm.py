"""(Bi)LSTM sequence tagger (DeepSpeech2/LibriSpeech stand-in).

A feature-frame encoder + (bi)directional LSTM + per-frame classifier.  The
paper's WER metric is proxied by per-frame token error rate (1 - accuracy);
the recurrence is the interesting part numerically — state carried across
time steps accumulates rounding error exactly like DeepSpeech2's RNN stack.

The recurrence uses ``jax.lax.scan`` so the lowered HLO stays compact (a
While loop) regardless of sequence length.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .. import qops
from . import Model


def _dense_init(key, a, b):
    scale = 1.0 / math.sqrt(a)
    return jax.random.uniform(key, (a, b), jnp.float32, -scale, scale)


def make(hp: dict) -> Model:
    in_dim = int(hp.get("in_dim", 32))
    hidden = int(hp.get("hidden", 64))
    num_classes = int(hp.get("num_classes", 16))
    seq = int(hp.get("seq", 32))
    batch = int(hp.get("batch", 16))
    bidir = bool(hp.get("bidirectional", True))

    dirs = ["fwd", "bwd"] if bidir else ["fwd"]

    def init(key):
        params = {}
        for d in dirs:
            key, k1, k2 = jax.random.split(key, 3)
            params[f"{d}.wx"] = _dense_init(k1, in_dim, 4 * hidden)
            params[f"{d}.wh"] = _dense_init(k2, hidden, 4 * hidden)
            params[f"{d}.b"] = jnp.zeros((4 * hidden,), jnp.float32)
        key, kk = jax.random.split(key)
        params["head.w"] = _dense_init(kk, hidden * len(dirs), num_classes)
        params["head.b"] = jnp.zeros((num_classes,), jnp.float32)
        return params

    def _lstm_dir(params, d, x, qcfg):
        """x: (S, B, in_dim) -> outputs (S, B, hidden)."""
        wx = qops.qparam(params[f"{d}.wx"], qcfg)
        wh = qops.qparam(params[f"{d}.wh"], qcfg)
        b = qops.qparam(params[f"{d}.b"], qcfg)
        bsz = x.shape[1]
        h0 = jnp.zeros((bsz, hidden), jnp.float32)
        c0 = jnp.zeros((bsz, hidden), jnp.float32)

        def cell(carry, xt):
            h, c = carry
            gates = qops.qout(
                jnp.matmul(xt, wx) + jnp.matmul(h, wh) + b, qcfg
            )
            i, f, g, o = jnp.split(gates, 4, axis=-1)
            i = qops.qsigmoid(i, qcfg)
            f = qops.qsigmoid(f, qcfg)
            g = qops.qtanh(g, qcfg)
            o = qops.qsigmoid(o, qcfg)
            c_new = qops.qout(f * c + i * g, qcfg)
            h_new = qops.qmul(o, qops.qtanh(c_new, qcfg), qcfg)
            return (h_new, c_new), h_new

        _, hs = jax.lax.scan(cell, (h0, c0), x)
        return hs

    def forward(params, x, qcfg):
        xt = qops.qdata(jnp.transpose(x, (1, 0, 2)), qcfg)  # (S,B,F)
        outs = [_lstm_dir(params, "fwd", xt, qcfg)]
        if bidir:
            rev = _lstm_dir(params, "bwd", xt[::-1], qcfg)[::-1]
            outs.append(rev)
        h = jnp.concatenate(outs, axis=-1)  # (S, B, H*dirs)
        s, b, hd = h.shape
        logits = qops.qlinear(
            h.reshape(s * b, hd), params["head.w"], params["head.b"], qcfg
        ).reshape(s, b, num_classes)
        return jnp.transpose(logits, (1, 0, 2))  # (B, S, C)

    def loss_and_metric(params, x, y, qcfg):
        logits = forward(params, x, qcfg)
        loss = qops.softmax_xent(logits, y, qcfg)
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return loss, acc  # WER proxy = 1 - acc, computed by the coordinator

    def predict(params, x, qcfg):
        logits = forward(params, x, qcfg)
        # predicted class of the first frame, as the per-example eval vector
        return jnp.argmax(logits[:, 0, :], -1).astype(jnp.float32)

    return Model(
        name="lstm",
        init=init,
        loss_and_metric=loss_and_metric,
        predict=predict,
        x_spec=((batch, seq, in_dim), "f32"),
        y_spec=((batch, seq), "i32"),
        metric_name="wer",  # coordinator reports 1 - accuracy
    )
