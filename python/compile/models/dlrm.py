"""DLRM family (Criteo Kaggle / Terabyte stand-ins; Naumov et al. 2019).

Bottom MLP over dense features, embedding lookups for categorical features,
pairwise dot-product feature interaction, top MLP, BCE loss.  Embedding
tables dominate the weight count — which is why the paper's Figure 9 shows
the highest update-cancellation rates here — and the x batch packs dense
features and categorical indices side by side:

    x = [dense (B, dense_dim) floats | indices (B, num_tables) as floats]

Indices travel as f32 (values are exact integers < 2^24) so the batch stays
a single tensor; the graph casts them back to i32 for the gather.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .. import qops
from . import Model


def _mlp_init(key, dims, prefix, params):
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, kk = jax.random.split(key)
        scale = math.sqrt(2.0 / a)
        params[f"{prefix}{i}.w"] = (
            jax.random.normal(kk, (a, b), jnp.float32) * scale
        )
        params[f"{prefix}{i}.b"] = jnp.zeros((b,), jnp.float32)
    return key


def _mlp_apply(params, prefix, n, h, qcfg, final_relu=True):
    for i in range(n):
        h = qops.qlinear(h, params[f"{prefix}{i}.w"], params[f"{prefix}{i}.b"], qcfg)
        if i + 1 < n or final_relu:
            h = qops.qrelu(h, qcfg)
    return h


def make(hp: dict) -> Model:
    num_tables = int(hp.get("num_tables", 8))
    table_size = int(hp.get("table_size", 1000))
    embed_dim = int(hp.get("embed_dim", 16))
    dense_dim = int(hp.get("dense_dim", 13))
    bottom = list(hp.get("bottom_mlp", [64, 16]))
    top = list(hp.get("top_mlp", [64, 32]))
    batch = int(hp.get("batch", 128))
    assert bottom[-1] == embed_dim, "bottom MLP must end at embed_dim"

    bot_dims = [dense_dim] + bottom
    n_feat = num_tables + 1  # embeddings + bottom-MLP output
    n_pairs = n_feat * (n_feat - 1) // 2
    top_dims = [n_pairs + embed_dim] + top + [1]

    def init(key):
        params = {}
        for t in range(num_tables):
            key, kk = jax.random.split(key)
            params[f"emb{t}"] = jax.random.uniform(
                kk,
                (table_size, embed_dim),
                jnp.float32,
                -1.0 / math.sqrt(table_size),
                1.0 / math.sqrt(table_size),
            )
        key = _mlp_init(key, bot_dims, "bot", params)
        _mlp_init(key, top_dims, "top", params)
        return params

    def forward(params, x, qcfg):
        dense = qops.qdata(x[:, :dense_dim], qcfg)
        idx = x[:, dense_dim:].astype(jnp.int32)  # exact small ints
        z = _mlp_apply(params, "bot", len(bot_dims) - 1, dense, qcfg)
        feats = [z]
        for t in range(num_tables):
            feats.append(qops.qembed(params[f"emb{t}"], idx[:, t], qcfg))
        f = jnp.stack(feats, axis=1)  # (B, n_feat, embed_dim)
        # pairwise dot-product interaction (one FMAC op, rounded output)
        inter = qops.qout(jnp.einsum("bne,bme->bnm", f, f), qcfg)
        iu, ju = jnp.triu_indices(n_feat, k=1)
        pairs = inter[:, iu, ju]  # (B, n_pairs)
        h = jnp.concatenate([z, pairs], axis=1)
        logit = _mlp_apply(
            params, "top", len(top_dims) - 1, h, qcfg, final_relu=False
        )
        return logit[:, 0]

    def loss_and_metric(params, x, y, qcfg):
        logit = forward(params, x, qcfg)
        loss = qops.bce_with_logits(logit, y, qcfg)
        acc = jnp.mean(((logit > 0.0) == (y > 0.5)).astype(jnp.float32))
        return loss, acc

    def predict(params, x, qcfg):
        # probabilities, so the rust side can compute AUC (paper's metric)
        return jax.nn.sigmoid(forward(params, x, qcfg))

    return Model(
        name="dlrm",
        init=init,
        loss_and_metric=loss_and_metric,
        predict=predict,
        x_spec=((batch, dense_dim + num_tables), "f32"),
        y_spec=((batch,), "f32"),
        metric_name="auc",
    )
